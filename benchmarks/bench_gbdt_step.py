"""§Perf pair 3 — the paper's own workload: one distributed-GBDT boosting
round, decomposed into proposal / binning / histogram / split stages with
REAL wall-clock timings (CPU backend; the only measurable pair in this
container) plus the hillclimb variants:

  hist-v0  scatter-add histogram (ref.py — GPU-style formulation)
  hist-v1  one-hot matmul, fp32  (the Pallas kernel's TPU formulation,
           executed through XLA:CPU as a dense contraction)
  hist-v2  v1 with bins pre-packed to uint8 (less index traffic)

and proposal random vs weighted-quantile vs GK (Table-2 T columns),
plus the headline trainer comparison: the single-compile lax.scan fit
(direct and histogram-subtraction growth) vs the unrolled per-round
reference loop (n_trees=50, max_depth=6).  Warm timings are
median-of-k (k>=5) interleaved repeats with the min/max spread;
wall-clock, round-step trace counts and the measured scatter-update
telemetry are written to ``BENCH_gbdt_step.json``.

``--smoke`` runs a tiny CI-sized workload instead and asserts the two
hard invariants (one round-step trace per scanned fit; subtraction
issues strictly fewer scatter updates than direct growth).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binning, boosting, proposal, tree as tree_lib
from repro.kernels import ops, ref

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_gbdt_step.json")


def _time(fn, reps=3):
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps * 1e6


def _hist_onehot(bins, node, gh, n_nodes, nbins):
    """One-hot matmul formulation (what the Pallas kernel does per tile)."""
    n, f = bins.shape
    idx = node[:, None] * nbins + bins                 # (n, f)
    width = n_nodes * nbins
    onehot = jax.nn.one_hot(idx, width, dtype=jnp.float32)   # (n, f, W)
    out = jnp.einsum("nfw,nc->fwc", onehot, gh)
    return out.reshape(f, n_nodes, nbins, 2).transpose(1, 0, 2, 3)


def run(csv_rows: list, *, update_json: bool = True) -> None:
    key = jax.random.PRNGKey(0)
    n, f, k = 200_000, 16, 32
    nbins = k + 1
    depth_nodes = 16
    x = jax.random.normal(key, (n, f))
    g = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    h = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (n,)))
    gh = jnp.stack([g, h], 1)
    node = jax.random.randint(jax.random.fold_in(key, 3), (n,), 0,
                              depth_nodes)

    # stage timings
    t_prop = _time(lambda: jax.block_until_ready(
        proposal.random_candidates(key, x, k)))
    csv_rows.append((f"gbdt_step/proposal_random", t_prop, f"n={n} f={f}"))
    cand = proposal.random_candidates(key, x, k)
    t_bin = _time(lambda: jax.block_until_ready(
        binning.bin_features(x, cand)))
    csv_rows.append((f"gbdt_step/binning", t_bin, ""))
    bins = binning.bin_features(x, cand)

    hist_fns = {
        "hist_v0_scatter": jax.jit(lambda b, nd, s: ref.hist_ref(
            b, nd, s, n_nodes=depth_nodes, nbins=nbins)),
        "hist_v1_onehot": jax.jit(lambda b, nd, s: _hist_onehot(
            b, nd, s, depth_nodes, nbins)),
    }
    outs = {}
    for name, fn in hist_fns.items():
        t = _time(lambda fn=fn: jax.block_until_ready(fn(bins, node, gh)))
        outs[name] = fn(bins, node, gh)
        csv_rows.append((f"gbdt_step/{name}", t,
                         f"{n / (t / 1e6) / 1e6:.1f}M rows/s"))
    err = float(jnp.abs(outs["hist_v0_scatter"]
                        - outs["hist_v1_onehot"]).max())
    csv_rows.append(("gbdt_step/hist_v0_vs_v1_err", 0.0, f"{err:.2e}"))

    # v2: uint8 bins
    bins8 = bins.astype(jnp.uint8)
    fn8 = jax.jit(lambda b, nd, s: ref.hist_ref(
        b.astype(jnp.int32), nd, s, n_nodes=depth_nodes, nbins=nbins))
    t8 = _time(lambda: jax.block_until_ready(fn8(bins8, node, gh)))
    csv_rows.append((f"gbdt_step/hist_v2_uint8bins", t8,
                     f"{n / (t8 / 1e6) / 1e6:.1f}M rows/s"))

    # v3: complex64-packed scatter (the 'packed' backend — CPU default)
    fnp = jax.jit(lambda b, nd, s: ref.hist_packed(
        b, nd, s, n_nodes=depth_nodes, nbins=nbins))
    tp = _time(lambda: jax.block_until_ready(fnp(bins, node, gh)))
    errp = float(jnp.abs(outs["hist_v0_scatter"]
                         - fnp(bins, node, gh)).max())
    csv_rows.append((f"gbdt_step/hist_v3_packed", tp,
                     f"{n / (tp / 1e6) / 1e6:.1f}M rows/s "
                     f"err_vs_v0={errp:.1e}"))

    # v4: level-batched packed scatter — the HistSpec entry point with
    # L=5 node assignments of the same rows in ONE complex64 scatter
    # (what a depth-5 grower pays per level, amortised across levels
    # when node ids are known up front).
    L = 5
    node_lvls = jnp.stack([
        jax.random.randint(jax.random.fold_in(key, 40 + l), (n,), 0,
                           depth_nodes) for l in range(L)])
    spec_l = ops.HistSpec(n_nodes=depth_nodes, nbins=nbins, n_levels=L,
                          backend="packed")
    fnl = jax.jit(lambda b, nd, s: ops.hist_levels(b, nd, s, spec_l))
    tl = _time(lambda: jax.block_until_ready(fnl(bins, node_lvls, gh)))
    csv_rows.append((f"gbdt_step/hist_v4_levels{L}_packed", tl,
                     f"{tl / L:.0f}us/level "
                     f"{n * L / (tl / 1e6) / 1e6:.1f}M row-levels/s"))

    # whole tree level (hist + split) through the HistSpec API
    spec5 = ops.HistSpec(n_nodes=depth_nodes, nbins=nbins, n_levels=5)
    t_level = _time(lambda: jax.block_until_ready(tree_lib.build_tree(
        bins, gh, cand, max_depth=5, spec=spec5)))
    csv_rows.append(("gbdt_step/full_tree_depth5", t_level, ""))

    # ------------------------------------------------------------------
    # Headline: single-compile scanned fit (direct and subtraction
    # growth) vs unrolled reference loop.  n_trees=50, max_depth=6 — the
    # acceptance workload.  The baseline is pinned to backend='ref' so
    # fit_reference follows the SEED's exact execution path (the
    # unrolled loop with the scatter hist, which is what backend='auto'
    # resolved to on CPU before this change); the scanned fits use the
    # default 'auto' (-> 'packed' on CPU).  'cold' includes
    # trace+compile; 'warm' is MEDIAN-of-k (k >= 5) over interleaved
    # refits with every jit cache hot, reported with the [min, max]
    # spread (interleaving so container CPU noise hits all trainers
    # alike).
    # ------------------------------------------------------------------
    nf, ff = 10_000, 16
    warm_reps = 7
    kf = jax.random.fold_in(key, 100)
    xf = jax.random.normal(kf, (nf, ff))
    wf = jax.random.normal(jax.random.fold_in(kf, 1), (ff,))
    yf = (xf @ wf > 0).astype(jnp.float32)
    cfg = boosting.GBDTConfig(n_trees=50, max_depth=6, n_candidates=32)
    cfg_seed = boosting.GBDTConfig(n_trees=50, max_depth=6,
                                   n_candidates=32, backend="ref")
    cfg_sub = dataclasses.replace(cfg, subtract=True)

    def fit_s(fn, c):
        t0 = time.perf_counter()
        m = fn(xf, yf, c, jax.random.PRNGKey(0))
        return time.perf_counter() - t0, m

    def med_spread(ts):
        return (round(statistics.median(ts), 4),
                [round(min(ts), 4), round(max(ts), 4)])

    # telemetry-enabled fit rides the same warm loop: per-round
    # TrainReport rows on the scan; the overhead vs the plain scanned
    # fit is the price of observability (must stay small — the report is
    # a handful of scalars per round next to the histogram work).
    cfg_tel = dataclasses.replace(cfg, telemetry=True)
    tr0 = boosting.round_trace_count()
    ref_cold, _ = fit_s(boosting.fit_reference, cfg_seed)
    scan_cold, _ = fit_s(boosting.fit, cfg)
    scan_traces = boosting.round_trace_count() - tr0
    tr0 = boosting.round_trace_count()
    sub_cold, _ = fit_s(boosting.fit, cfg_sub)
    sub_traces = boosting.round_trace_count() - tr0
    fit_s(boosting.fit, cfg_tel)               # compile (separate config)
    ref_warm, scan_warm, sub_warm, tel_warm = [], [], [], []
    for _ in range(warm_reps):
        t, m_ref = fit_s(boosting.fit_reference, cfg_seed)
        ref_warm.append(t)
        t, m_scan = fit_s(boosting.fit, cfg)
        scan_warm.append(t)
        t, m_sub = fit_s(boosting.fit, cfg_sub)
        sub_warm.append(t)
        t, m_tel = fit_s(boosting.fit, cfg_tel)
        tel_warm.append(t)
    ref_med, ref_spread = med_spread(ref_warm)
    scan_med, scan_spread = med_spread(scan_warm)
    sub_med, sub_spread = med_spread(sub_warm)
    tel_med, _ = med_spread(tel_warm)
    acc_gap = abs(boosting.accuracy(m_scan, xf, yf)
                  - boosting.accuracy(m_ref, xf, yf))
    acc_gap_sub = abs(boosting.accuracy(m_sub, xf, yf)
                      - boosting.accuracy(m_ref, xf, yf))
    tel_overhead_pct = 100 * (tel_med / scan_med - 1)
    csv_rows.append(("gbdt_step/fit50_telemetry_warm", tel_med * 1e6,
                     f"overhead={tel_overhead_pct:+.1f}% vs scanned"))

    # measured scatter updates, direct vs subtraction (one telemetry'd
    # subtract fit outside the timed loop; the counter is exact, not
    # timing-sensitive)
    _, m_sub_tel = fit_s(boosting.fit,
                         dataclasses.replace(cfg_sub, telemetry=True))
    upd_direct = float(np.asarray(m_tel.report.hist_updates).sum())
    upd_sub = float(np.asarray(m_sub_tel.report.hist_updates).sum())

    csv_rows.append(("gbdt_step/fit50_reference_warm", ref_med * 1e6,
                     f"cold={ref_cold:.2f}s "
                     f"spread=[{ref_spread[0]},{ref_spread[1]}]s"))
    csv_rows.append(("gbdt_step/fit50_scanned_warm", scan_med * 1e6,
                     f"cold={scan_cold:.2f}s traces={scan_traces} "
                     f"spread=[{scan_spread[0]},{scan_spread[1]}]s"))
    csv_rows.append(("gbdt_step/fit50_subtract_warm", sub_med * 1e6,
                     f"cold={sub_cold:.2f}s traces={sub_traces} "
                     f"{100 * (1 - sub_med / scan_med):+.1f}% vs direct "
                     f"updates {upd_sub:.0f}/{upd_direct:.0f}"))
    if not update_json:
        csv_rows.append(("gbdt_step/fit50", 0.0,
                         "(dry run: BENCH_gbdt_step.json NOT updated)"))
        return

    rec = {
        "workload": {"n": nf, "f": ff, "n_trees": cfg.n_trees,
                     "max_depth": cfg.max_depth,
                     "n_candidates": cfg.n_candidates,
                     "strategy": cfg.strategy,
                     "platform": jax.default_backend(),
                     "baseline_backend": "ref",
                     "scanned_backend": ops.resolve(cfg.backend)},
        "timing_protocol": {"warm_reps": warm_reps, "stat": "median",
                            "spread": "min_max",
                            "interleaved": True},
        "reference_fit_s": {"cold": round(ref_cold, 4),
                            "warm": ref_med, "warm_spread": ref_spread},
        "scanned_fit_s": {"cold": round(scan_cold, 4),
                          "warm": scan_med, "warm_spread": scan_spread},
        "subtract_fit_s": {"cold": round(sub_cold, 4),
                           "warm": sub_med, "warm_spread": sub_spread},
        "warm_speedup": round(ref_med / scan_med, 3),
        "warm_reduction_pct": round(100 * (1 - scan_med / ref_med), 1),
        "cold_reduction_pct": round(100 * (1 - scan_cold / ref_cold), 1),
        "subtract_vs_direct_warm_pct": round(
            100 * (1 - sub_med / scan_med), 1),
        "round_step_traces_scanned_fit": scan_traces,
        "round_step_traces_subtract_fit": sub_traces,
        "accuracy_gap_scan_vs_ref": round(acc_gap, 6),
        "accuracy_gap_subtract_vs_ref": round(acc_gap_sub, 6),
        "scatter_updates": {
            "direct_total": upd_direct,
            "subtract_total": upd_sub,
            "reduction_ratio": round(upd_direct / upd_sub, 3),
            "note": "measured per-fit scatter updates (rows x features "
                    "summed over levels and rounds) from "
                    "TrainReport.hist_updates",
        },
        "telemetry": {
            "warm_fit_s": tel_med,
            "overhead_pct_vs_scanned_warm": round(tel_overhead_pct, 1),
            "summary": m_tel.report.summarize(),
        },
    }
    with open(_JSON_PATH, "w") as fh:
        json.dump(rec, fh, indent=1)


def smoke() -> None:
    """CI-sized invariant check (seconds, not minutes): one round-step
    trace per scanned fit, and subtraction growth must issue strictly
    fewer scatter updates than direct growth while fitting the exact
    same forest.  Exits non-zero via AssertionError on violation."""
    key = jax.random.PRNGKey(0)
    n, f = 2000, 6
    x = jax.random.normal(key, (n, f))
    w = jax.random.normal(jax.random.fold_in(key, 1), (f,))
    y = (x @ w > 0).astype(jnp.float32)
    fits = {}
    for name, sub in (("direct", False), ("subtract", True)):
        cfg = boosting.GBDTConfig(n_trees=8, max_depth=4, n_candidates=8,
                                  subtract=sub, telemetry=True)
        tr0 = boosting.round_trace_count()
        m = boosting.fit(x, y, cfg, jax.random.PRNGKey(0))
        traces = boosting.round_trace_count() - tr0
        assert traces == 1, \
            f"{name}: round_step_traces_scanned_fit={traces}, want 1"
        fits[name] = (m, float(np.asarray(m.report.hist_updates).sum()))
    (m_dir, upd_dir), (m_sub, upd_sub) = fits["direct"], fits["subtract"]
    assert 0 < upd_sub < upd_dir, \
        f"subtract updates {upd_sub} not strictly below direct {upd_dir}"
    for a, b in zip(m_dir.forest, m_sub.forest):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    print(f"SMOKE OK: traces=1/fit, scatter updates direct={upd_dir:.0f} "
          f"subtract={upd_sub:.0f} ({upd_dir / upd_sub:.2f}x), "
          "forests identical")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--update", action="store_true",
                    help="write the fit50 record to BENCH_gbdt_step.json "
                         "(default: dry run, print timings only)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI invariant check (trace count, scatter-"
                         "update reduction); no timings, no JSON write")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    rows: list = []
    run(rows, update_json=args.update)
    for name, us, note in rows:
        print(f"{name:40s} {us:12.1f} us  {note}")
    if args.update:
        print(f"updated {os.path.abspath(_JSON_PATH)}")


if __name__ == "__main__":
    main()
