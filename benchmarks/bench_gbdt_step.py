"""§Perf pair 3 — the paper's own workload: one distributed-GBDT boosting
round, decomposed into proposal / binning / histogram / split stages with
REAL wall-clock timings (CPU backend; the only measurable pair in this
container) plus the hillclimb variants:

  hist-v0  scatter-add histogram (ref.py — GPU-style formulation)
  hist-v1  one-hot matmul, fp32  (the Pallas kernel's TPU formulation,
           executed through XLA:CPU as a dense contraction)
  hist-v2  v1 with bins pre-packed to uint8 (less index traffic)

and proposal random vs weighted-quantile vs GK (Table-2 T columns).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import binning, boosting, proposal, tree as tree_lib
from repro.kernels import ref


def _time(fn, reps=3):
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps * 1e6


def _hist_onehot(bins, node, gh, n_nodes, nbins):
    """One-hot matmul formulation (what the Pallas kernel does per tile)."""
    n, f = bins.shape
    idx = node[:, None] * nbins + bins                 # (n, f)
    width = n_nodes * nbins
    onehot = jax.nn.one_hot(idx, width, dtype=jnp.float32)   # (n, f, W)
    out = jnp.einsum("nfw,nc->fwc", onehot, gh)
    return out.reshape(f, n_nodes, nbins, 2).transpose(1, 0, 2, 3)


def run(csv_rows: list) -> None:
    key = jax.random.PRNGKey(0)
    n, f, k = 200_000, 16, 32
    nbins = k + 1
    depth_nodes = 16
    x = jax.random.normal(key, (n, f))
    g = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    h = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (n,)))
    gh = jnp.stack([g, h], 1)
    node = jax.random.randint(jax.random.fold_in(key, 3), (n,), 0,
                              depth_nodes)

    # stage timings
    t_prop = _time(lambda: jax.block_until_ready(
        proposal.random_candidates(key, x, k)))
    csv_rows.append((f"gbdt_step/proposal_random", t_prop, f"n={n} f={f}"))
    cand = proposal.random_candidates(key, x, k)
    t_bin = _time(lambda: jax.block_until_ready(
        binning.bin_features(x, cand)))
    csv_rows.append((f"gbdt_step/binning", t_bin, ""))
    bins = binning.bin_features(x, cand)

    hist_fns = {
        "hist_v0_scatter": jax.jit(lambda b, nd, s: ref.hist_ref(
            b, nd, s, n_nodes=depth_nodes, nbins=nbins)),
        "hist_v1_onehot": jax.jit(lambda b, nd, s: _hist_onehot(
            b, nd, s, depth_nodes, nbins)),
    }
    outs = {}
    for name, fn in hist_fns.items():
        t = _time(lambda fn=fn: jax.block_until_ready(fn(bins, node, gh)))
        outs[name] = fn(bins, node, gh)
        csv_rows.append((f"gbdt_step/{name}", t,
                         f"{n / (t / 1e6) / 1e6:.1f}M rows/s"))
    err = float(jnp.abs(outs["hist_v0_scatter"]
                        - outs["hist_v1_onehot"]).max())
    csv_rows.append(("gbdt_step/hist_v0_vs_v1_err", 0.0, f"{err:.2e}"))

    # v2: uint8 bins
    bins8 = bins.astype(jnp.uint8)
    fn8 = jax.jit(lambda b, nd, s: ref.hist_ref(
        b.astype(jnp.int32), nd, s, n_nodes=depth_nodes, nbins=nbins))
    t8 = _time(lambda: jax.block_until_ready(fn8(bins8, node, gh)))
    csv_rows.append((f"gbdt_step/hist_v2_uint8bins", t8,
                     f"{n / (t8 / 1e6) / 1e6:.1f}M rows/s"))

    # whole tree level (hist + split)
    t_level = _time(lambda: jax.block_until_ready(tree_lib.build_tree(
        bins, gh, cand, max_depth=5, nbins=nbins)))
    csv_rows.append(("gbdt_step/full_tree_depth5", t_level, ""))
