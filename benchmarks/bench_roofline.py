"""Roofline summary: reads the dry-run artifacts (experiments/dryrun/*.json)
and emits one CSV row per (arch x shape x mesh) with the three terms.

Run ``python -m repro.launch.dryrun --all`` first; rows are skipped (with a
note) for combos whose artifact is missing.
"""

from __future__ import annotations

import glob
import json
import os


def run(csv_rows: list) -> None:
    paths = sorted(glob.glob("experiments/dryrun/*.json"))
    if not paths:
        csv_rows.append(("roofline/missing", 0.0,
                         "run python -m repro.launch.dryrun --all first"))
        return
    for p in paths:
        with open(p) as f:
            rec = json.load(f)
        tag = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec["status"] != "ok":
            csv_rows.append((tag, 0.0, rec["status"]))
            continue
        r = rec.get("roofline")
        if not r:
            csv_rows.append((tag, 0.0, "lowering-proof only (multi-pod)"))
            continue
        step_us = max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6
        csv_rows.append((
            tag, step_us,
            f"compute={r['compute_s']*1e3:.2f}ms "
            f"memory={r['memory_s']*1e3:.2f}ms "
            f"collective={r['collective_s']*1e3:.2f}ms "
            f"dom={r['dominant']} "
            f"useful={rec.get('useful_flops_ratio', 0):.2f}"))
