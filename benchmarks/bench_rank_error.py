"""Fig. 2 reproduction: normalised rank error vs subset size k.

Random selection vs deterministic equi-rank (GK-limit) binning on random
smooth objectives, against the 1/(k+1) closed form of Theorem 1.
"""

from __future__ import annotations

import time

from repro.core import rank_error


def run(csv_rows: list) -> None:
    t0 = time.perf_counter()
    out = rank_error.fig2_experiment(seed=0, n=2048,
                                     ks=[2, 4, 8, 16, 32, 64], trials=32)
    dt = (time.perf_counter() - t0) * 1e6
    for k, r, q, t in zip(out["k"], out["random"], out["quantile"],
                          out["theory"]):
        csv_rows.append((f"fig2/k={k}/random", dt / len(out['k']),
                         f"E={r:.4f} theory={t:.4f}"))
        csv_rows.append((f"fig2/k={k}/quantile", dt / len(out['k']),
                         f"E={q:.4f} theory={t:.4f}"))
    # the claim: |random - quantile| small relative to theory
    worst = max(abs(r - q) for r, q in zip(out["random"], out["quantile"]))
    csv_rows.append(("fig2/max_gap_random_vs_quantile", dt,
                     f"{worst:.4f}"))
