"""Table 2 reproduction: DT / GBDT accuracy + proposal time, S vs Q.

Synthetic analogues of the paper's dataset families (see
repro/data/tabular.py), at reduced row counts, over the paper's bin
sweep.  Columns mirror the paper: DT(S), DT(Q), XGB(S), XGB(Q), T(S),
T(Q) — here S = random sampling, Q = weighted-quantile sketch.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import boosting
from repro.data import make_dataset

DATASETS = [
    ("susy-like", 20_000, 5_000),
    ("higgs-like", 20_000, 5_000),
    ("wiretap-like", 10_000, 2_500),
    ("pjm-like", 10_000, 2_500),
]
BINS = [10, 50]


def _metric(model, x, y, task):
    if task == "class":
        return boosting.accuracy(model, x, y)
    return boosting.mape(model, x, y)


def run(csv_rows: list) -> None:
    import jax.numpy as jnp
    from repro.core import proposal

    for name, ntr, nte in DATASETS:
        xtr, ytr, xte, yte, task = make_dataset(name, ntr, nte)
        obj = "logistic" if task == "class" else "mse"
        n_trees_dt, n_trees_xgb = 1, (20 if task == "class" else 50)
        for bins in BINS:
            # warm the proposal jit caches for THESE shapes so T columns
            # measure the algorithm, not XLA compilation
            xj = jnp.asarray(xtr)
            hj = jnp.ones(xtr.shape[0])
            jax.block_until_ready(proposal.random_candidates(
                jax.random.PRNGKey(0), xj, bins))
            jax.block_until_ready(proposal.weighted_quantile_candidates(
                xj, hj, bins))
            row = {}
            for tag, strat in (("S", "random"), ("Q", "weighted_quantile")):
                t0 = time.perf_counter()
                cfg = boosting.GBDTConfig(
                    n_trees=n_trees_xgb, max_depth=6, n_candidates=bins,
                    strategy=strat, objective=obj)
                m = boosting.fit(xtr, ytr, cfg, jax.random.PRNGKey(0))
                fit_us = (time.perf_counter() - t0) * 1e6
                row[f"XGB({tag})"] = _metric(m, xte, yte, task)
                row[f"T({tag})"] = m.proposal_seconds * 1e3   # ms, Table 2
                # single tree (DT columns)
                cfg1 = boosting.GBDTConfig(
                    n_trees=1, max_depth=6, n_candidates=bins,
                    strategy=strat, objective=obj)
                m1 = boosting.fit(xtr, ytr, cfg1, jax.random.PRNGKey(0))
                row[f"DT({tag})"] = _metric(m1, xte, yte, task)
                csv_rows.append((f"table2/{name}/bins={bins}/{tag}",
                                 fit_us,
                                 f"DT={row[f'DT({tag})']:.4f} "
                                 f"XGB={row[f'XGB({tag})']:.4f} "
                                 f"Tprop_ms={row[f'T({tag})']:.1f}"))
            gap = abs(row["XGB(S)"] - row["XGB(Q)"])
            csv_rows.append((f"table2/{name}/bins={bins}/S_vs_Q_gap", 0.0,
                             f"{gap:.4f}"))
