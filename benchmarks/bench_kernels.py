"""Kernel micro-benchmarks (ref backend timing on CPU + interpret-mode
correctness deltas; real TPU timing is out of scope for this container)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, reps=5):
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps * 1e6


def run(csv_rows: list) -> None:
    key = jax.random.PRNGKey(0)

    # histogram: the GBDT hot spot
    n, f, nbins, nn = 200_000, 16, 64, 32
    bins = jax.random.randint(key, (n, f), 0, nbins)
    node = jax.random.randint(key, (n,), 0, nn)
    gh = jax.random.normal(key, (n, 2))
    spec = ops.HistSpec(n_nodes=nn, nbins=nbins, n_levels=1, backend="ref")
    t = _time(lambda: ops.hist_levels(bins, node[None], gh, spec)[0])
    rows_per_s = n / (t / 1e6)
    csv_rows.append((f"hist/n={n}xf={f}", t, f"{rows_per_s/1e6:.1f}M rows/s"))

    # interpret-mode correctness vs ref (small shape)
    b2 = bins[:2048]
    n2 = node[:2048]
    g2 = gh[:2048]
    ispec = ops.HistSpec(n_nodes=nn, nbins=nbins, n_levels=1,
                         backend="interpret")
    hp = ops.hist_levels(b2, n2[None], g2, ispec)[0]
    hr = ref.hist_ref(b2, n2, g2, n_nodes=nn, nbins=nbins)
    csv_rows.append(("hist/interpret_max_err", 0.0,
                     f"{float(jnp.abs(hp - hr).max()):.2e}"))

    # split gain
    hist = jnp.abs(jax.random.normal(key, (64, 32, 65, 2)))
    t = _time(lambda: ops.split_gain(hist, backend="ref"))
    csv_rows.append(("split_gain/64x32x65", t, ""))

    # flash attention (ref) prefill-ish tile
    q = jax.random.normal(key, (1, 8, 1024, 128), jnp.bfloat16)
    k = jax.random.normal(key, (1, 2, 1024, 128), jnp.bfloat16)
    v = jax.random.normal(key, (1, 2, 1024, 128), jnp.bfloat16)
    t = _time(lambda: ops.flash_attention(q, k, v, backend="ref"), reps=3)
    flops = 4 * 1024 * 1024 * 128 * 8
    csv_rows.append((f"flash_attention/1x8x1024x128", t,
                     f"{flops / (t / 1e6) / 1e9:.1f} GFLOP/s(ref)"))
    ap = ops.flash_attention(q[:, :, :256], k[:, :, :256], v[:, :, :256],
                             backend="interpret")
    ar = ref.attention_ref(q[:, :, :256], k[:, :, :256], v[:, :, :256])
    csv_rows.append(("flash_attention/interpret_max_err", 0.0,
                     f"{float(jnp.abs(ap.astype(jnp.float32) - ar.astype(jnp.float32)).max()):.2e}"))
