"""Proposal-stage timing: T(S) vs T(Q) scaling with rows (Table 2 T cols).

Random sampling vs GK streaming summary vs vectorised weighted-quantile
(sort-based) — the compute side of the paper's 2-6x speedup claim.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import proposal


def _time(fn, reps=3):
    fn()   # warmup / jit
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def run(csv_rows: list) -> None:
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(0)
    k = 32
    for n in (10_000, 100_000, 500_000):
        x = rng.normal(size=(n, 16)).astype(np.float32)
        xj = jax.numpy.asarray(x)
        h = jax.numpy.ones((n,))

        t_rand = _time(lambda: jax.block_until_ready(
            proposal.random_candidates(key, xj, k)))
        t_wq = _time(lambda: jax.block_until_ready(
            proposal.weighted_quantile_candidates(xj, h, k)))
        csv_rows.append((f"proposal/n={n}/random", t_rand, f"k={k}"))
        csv_rows.append((f"proposal/n={n}/weighted_quantile", t_wq,
                         f"k={k} slowdown={t_wq / t_rand:.2f}x"))
        if n <= 100_000:   # GK is host-side and deliberately slow
            t_gk = _time(lambda: proposal.gk_quantile_candidates(
                x[:, :4], k), reps=1)
            csv_rows.append((f"proposal/n={n}/gk_summary_4feat", t_gk,
                             f"k={k} slowdown={t_gk / t_rand:.1f}x"))
