"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Modules:
  bench_rank_error     — Fig. 2 (rank error vs k, random vs quantile)
  bench_table2         — Table 2 (DT/GBDT accuracy + proposal time, S vs Q)
  bench_proposal_time  — Table 2 T columns (scaling with rows)
  bench_kernels        — Pallas kernel hot spots
  bench_roofline       — §Roofline terms from the dry-run artifacts
  bench_predict        — batched inference engine vs per-tree scan
"""

from __future__ import annotations

import sys
import traceback

from . import (bench_gbdt_step, bench_kernels, bench_predict,
               bench_proposal_time, bench_rank_error, bench_roofline,
               bench_table2)

MODULES = [
    ("rank_error", bench_rank_error),
    ("table2", bench_table2),
    ("proposal_time", bench_proposal_time),
    ("kernels", bench_kernels),
    ("gbdt_step", bench_gbdt_step),
    ("roofline", bench_roofline),
    ("predict", bench_predict),
]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    rows: list = []
    print("name,us_per_call,derived")
    for name, mod in MODULES:
        if only and only != name:
            continue
        try:
            n0 = len(rows)
            mod.run(rows)
            for r in rows[n0:]:
                print(f"{r[0]},{r[1]:.1f},{r[2]}", flush=True)
        except Exception:
            traceback.print_exc()
            print(f"{name}/ERROR,0,failed")
            raise SystemExit(1)


if __name__ == "__main__":
    main()
