"""§Perf pair 4 — forest inference: the batched level-synchronous
traversal engine (repro.core.predict) vs the seed per-tree ``lax.scan``
predictor, at the acceptance workload (500 trees x depth 6, CPU):

  scan_baseline   sequential per-tree scan (tree._forest_predict_scan,
                  the seed forest_predict_raw) — n_trees dependent
                  dispatch chains of max_depth gathers each
  engine_raw      level-synchronous chunked traversal on raw floats
                  (ONE fused gather+compare per depth level per chunk)
  engine_binned   same engine on pre-binned uint8->int32 bin ids
                  (binning done once outside the timed loop, the
                  serving amortisation)

Each variant is timed as warm full-batch predicts (median semantics
live in the PredictReport percentiles; requests are interleaved-free
full repeats after a 2x warmup).  Wall-clock, rows/s, p50/p99 and the
traversal trace count are written to ``BENCH_predict.json`` with
``--update``.

``--smoke`` runs a tiny CI-sized check instead and asserts the two hard
invariants: ONE traversal-chunk trace per fresh compiled predict
regardless of n_trees (and zero on repeat calls), and the batched
engine bit-identical to the per-tree scan oracle.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import predict as predict_lib, tree as tree_lib
from repro.kernels import ops
from repro.launch.serve_gbdt import synthetic_gbdt
from repro.obs import PredictReport

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_predict.json")


def _measure_interleaved(fns: dict, *, reps: int) -> dict:
    """Per-rep warm wall-clock seconds for each variant, measured
    rep-major (scan, raw, binned, scan, raw, ...) after 2 untimed
    warmup calls each — container CPU noise hits every variant alike,
    so the recorded speedup ratios are robust to frequency drift."""
    for fn in fns.values():
        for _ in range(2):
            jax.block_until_ready(fn())
    lat = {name: np.empty((reps,), np.float64) for name in fns}
    for i in range(reps):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            lat[name][i] = time.perf_counter() - t0
    return lat


def run(csv_rows: list, *, update_json: bool = False) -> None:
    n_trees, depth, f, k = 500, 6, 32, 32
    rows, reps = 50_000, 7
    chunk = predict_lib.DEFAULT_TREE_CHUNK
    backend = ops.resolve("auto")

    model = synthetic_gbdt(n_trees=n_trees, max_depth=depth, n_features=f,
                           n_candidates=k, seed=0)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(rows, f)).astype(np.float32))
    bins = jnp.asarray(model.bin_features(x), jnp.int32)

    engine_desc = {"n_trees": n_trees, "max_depth": depth,
                   "n_features": f, "tree_chunk": chunk,
                   "backend": backend}

    def scan_fn():
        return tree_lib._forest_predict_scan(model.forest, x,
                                             max_depth=depth)

    def raw_fn():
        return predict_lib.forest_predict(model.forest, x, max_depth=depth,
                                          tree_chunk=chunk)

    def binned_fn():
        return predict_lib.forest_predict(model.forest, bins,
                                          max_depth=depth, binned=True,
                                          tree_chunk=chunk)

    # exactness first — a fast wrong predictor is worthless.  The first
    # raw_fn() call is also the fresh-compile probe: exactly one
    # traversal-chunk trace for the whole 500-tree forest.
    base = np.asarray(scan_fn())
    tr0 = predict_lib.traverse_trace_count()
    identical_raw = np.array_equal(np.asarray(raw_fn()), base)
    traces = predict_lib.traverse_trace_count() - tr0
    identical_binned = np.array_equal(np.asarray(binned_fn()), base)
    assert identical_raw, "engine_raw diverged from the per-tree scan"
    assert identical_binned, "engine_binned diverged from the per-tree scan"
    assert traces <= 1, f"traversal traces per fresh predict: {traces}"

    lats = _measure_interleaved(
        {"scan_baseline": scan_fn, "engine_raw": raw_fn,
         "engine_binned": binned_fn}, reps=reps)
    reports = {}
    for name, lat in lats.items():
        baseline = (reports["scan_baseline"].summarize()["rows_per_s"]
                    if name != "scan_baseline" else 0.0)
        reports[name] = PredictReport(
            latencies_s=lat, rows_per_request=rows,
            engine={**engine_desc, "variant": name,
                    "binned": name == "engine_binned"},
            baseline_rows_per_s=baseline)
        s = reports[name].summarize()
        note = (f"{s['rows_per_s'] / 1e6:.2f}M rows/s "
                f"p99={s['latency_ms']['p99']:.0f}ms")
        if "speedup_vs_scan" in s:
            note += f" {s['speedup_vs_scan']:.1f}x vs scan"
        csv_rows.append((f"predict/{name}", s["latency_ms"]["mean"] * 1e3,
                         note))
    csv_rows.append(("predict/traversal_traces_fresh", 0.0,
                     f"{traces} (want <= 1 for any n_trees)"))

    if not update_json:
        csv_rows.append(("predict/500x6", 0.0,
                         "(dry run: BENCH_predict.json NOT updated)"))
        return

    rec = {
        "workload": {"n_trees": n_trees, "max_depth": depth, "rows": rows,
                     "n_features": f, "n_candidates": k,
                     "tree_chunk": chunk, "backend": backend,
                     "platform": jax.default_backend()},
        "timing_protocol": {"warm_reps": reps, "warmup_calls": 2,
                            "scope": "full-batch predict wall-clock"},
        "bit_identical_engine_vs_scan": {"raw": bool(identical_raw),
                                         "binned": bool(identical_binned)},
        "traversal_traces_per_fresh_predict": int(traces),
        "variants": {name: json.loads(r.to_json())
                     for name, r in reports.items()},
    }
    with open(_JSON_PATH, "w") as fh:
        json.dump(rec, fh, indent=1)


def smoke() -> None:
    """CI-sized invariant check (seconds): one traversal-chunk trace per
    fresh compiled predict regardless of n_trees (zero when the cache is
    hot), and batched predict bit-identical to the per-tree scan oracle.
    Exits non-zero via AssertionError on violation."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(600, 8)).astype(np.float32))
    chunk = 8
    traces_per_forest = {}
    for n_trees in (24, 56):
        model = synthetic_gbdt(n_trees=n_trees, max_depth=4, n_features=8,
                               n_candidates=9, seed=n_trees)
        base = np.asarray(tree_lib._forest_predict_scan(model.forest, x,
                                                        max_depth=4))
        tr0 = predict_lib.traverse_trace_count()
        out = predict_lib.forest_predict(model.forest, x, max_depth=4,
                                         tree_chunk=chunk)
        fresh = predict_lib.traverse_trace_count() - tr0
        assert np.array_equal(np.asarray(out), base), \
            f"engine != scan oracle at n_trees={n_trees}"
        tr0 = predict_lib.traverse_trace_count()
        predict_lib.forest_predict(model.forest, x, max_depth=4,
                                   tree_chunk=chunk)
        repeat = predict_lib.traverse_trace_count() - tr0
        assert fresh <= 1 and repeat == 0, \
            (f"n_trees={n_trees}: fresh={fresh} (want <=1), "
             f"repeat={repeat} (want 0)")
        traces_per_forest[n_trees] = fresh
    print(f"SMOKE OK: traces per fresh predict {traces_per_forest} "
          "(<=1 each, 0 warm), batched == per-tree scan bit-for-bit")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--update", action="store_true",
                    help="write the 500x6 record to BENCH_predict.json "
                         "(default: dry run, print timings only)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI invariant check (trace count, "
                         "bit-identity); no timings, no JSON write")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    rows: list = []
    run(rows, update_json=args.update)
    for name, us, note in rows:
        print(f"{name:40s} {us:12.1f} us  {note}")
    if args.update:
        print(f"updated {os.path.abspath(_JSON_PATH)}")


if __name__ == "__main__":
    main()
