"""Algorithm 1 end-to-end: distributed GBDT over 8 (forced) host devices.

Each worker samples candidates from its local shard at data-read time;
per boosting round the candidate pools are all-gathered and resampled
with a shared key (the paper's AllReduce-combine-resample); gradient
histograms are psum'd inside the tree builder.  The per-worker loop is
the same single-compile ``lax.scan`` round runner as the single-host
trainer, so each worker traces its round step exactly once.

Run:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/distributed_gbdt.py
"""

import os

if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

import jax                                                      # noqa: E402
import numpy as np                                              # noqa: E402
from jax.sharding import Mesh                                   # noqa: E402

import repro                                                    # noqa: E402
from repro.data import make_dataset                             # noqa: E402


def main() -> None:
    print(f"devices: {len(jax.devices())}")
    xtr, ytr, xte, yte, _ = make_dataset("higgs-like", 32_768, 8_192)
    mesh = Mesh(np.array(jax.devices()).reshape(-1), ("data",))

    for strat in ("random", "weighted_quantile"):
        # telemetry=True: per-round TrainReport with psum'd global loss
        # stats and the estimated per-round collective payload
        cfg = repro.GBDTConfig(n_trees=10, max_depth=5,
                               n_candidates=32, strategy=strat,
                               telemetry=True)
        m = repro.fit_distributed(xtr, ytr, cfg, mesh,
                                  jax.random.PRNGKey(0))
        acc = repro.accuracy(m, xte, yte)
        coll = m.report.summarize()["collective_bytes"]
        print(f"  {strat:18s} acc={acc:.4f}  "
              f"({mesh.shape['data']} workers, Algorithm 1)")
        print(f"  {'':18s} loss {float(m.report.train_loss[0]):.4f} -> "
              f"{float(m.report.train_loss[-1]):.4f}, "
              f"~{coll['per_round'] / 1024:.1f} KiB collectives/round "
              f"(all_gather {coll['all_gather_total'] / 1024:.1f} KiB + "
              f"psum {coll['psum_total'] / 1024:.1f} KiB total)")

    # single-host reference
    cfg = repro.GBDTConfig(n_trees=10, max_depth=5, n_candidates=32)
    m1 = repro.fit(xtr, ytr, cfg, jax.random.PRNGKey(0))
    print(f"  {'single-host':18s} acc={repro.accuracy(m1, xte, yte):.4f}")


if __name__ == "__main__":
    main()
