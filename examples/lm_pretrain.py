"""End-to-end LM training driver: train a ~100M-param model for a few
hundred steps on the synthetic token pipeline with checkpointing.

The default arch is xlstm-125m at FULL size (it is the one assigned
architecture small enough to train honestly on CPU); pass --smoke for the
reduced variant of any other arch.

Run:  PYTHONPATH=src python examples/lm_pretrain.py [--steps 300]
"""

import argparse

from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (fast CPU demo)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    losses = train(args.arch, smoke=args.smoke, steps_n=args.steps,
                   batch=args.batch, seq=args.seq, lr=3e-4,
                   ckpt_dir=args.ckpt_dir,
                   ckpt_every=max(50, args.steps // 4))
    drop = losses[0] - losses[-1]
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} (drop {drop:.3f})")
    if args.steps >= 100:
        assert drop > 0, "training failed to reduce loss"
    elif drop <= 0:
        print("note: <100 steps is a smoke run; loss movement at full "
              "model size needs a few hundred steps")


if __name__ == "__main__":
    main()
