"""Batched serving demo: prefill + greedy decode with KV cache / recurrent
state, across attention, MoE and SSM families.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

from repro.launch.serve import generate


def main() -> None:
    for arch in ("glm4-9b", "deepseek-moe-16b", "zamba2-2.7b"):
        print(f"--- {arch} (reduced config) ---")
        toks = generate(arch, smoke=True, batch=4, prompt_len=16, gen=8)
        print(f"  first sequence: {toks[0].tolist()}")


if __name__ == "__main__":
    main()
