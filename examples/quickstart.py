"""Quickstart: the paper in 60 seconds.

Trains a GBDT with the paper's random split-point proposal and with the
XGBoost-style weighted-quantile sketch on a synthetic SUSY-like dataset,
then prints the accuracy parity + proposal speedup (Table 2's claim) and
the Theorem-1 rank-error curve (Fig. 2's claim).

The trainer is the single-compile ``lax.scan`` round runner: the whole
n_trees-round fit is one compiled program (watch the reported round-step
trace count stay at one per config), and the fitted ensemble comes back
as a stacked :class:`repro.core.tree.Forest`.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax

import repro
from repro.core import rank_error                  # research experiment,
from repro.core.boosting import round_trace_count  # trace diagnostic —
from repro.data import make_dataset       # all outside the stable surface


def main() -> None:
    print("=== 1. GBDT: random sampling (S) vs quantile sketch (Q) ===")
    xtr, ytr, xte, yte, _ = make_dataset("susy-like", 20_000, 5_000)
    results = {}
    for strat in ("random", "weighted_quantile"):
        cfg = repro.GBDTConfig(n_trees=20, max_depth=6,
                               n_candidates=32, strategy=strat)
        m = repro.fit(xtr, ytr, cfg, jax.random.PRNGKey(0))
        results[strat] = dict(
            acc=repro.accuracy(m, xte, yte),
            fit_s=m.fit_seconds,
            trees=m.forest.n_trees)
    for k, v in results.items():
        print(f"  {k:18s} acc={v['acc']:.4f} "
              f"fit={v['fit_s']:.1f}s forest={v['trees']} trees")
    gap = abs(results['random']['acc']
              - results['weighted_quantile']['acc'])
    print(f"  accuracy gap = {gap:.4f}  (paper: ~0, Table 2)")
    print(f"  round-step traces = {round_trace_count()} "
          f"(one compile per config — O(1) in n_trees)")

    print("\n=== 2. Telemetry: per-round TrainReport ===")
    # telemetry rows ride the same compiled scan (still one round-step
    # trace); the report is a struct-of-arrays of per-round scalars
    cfg = repro.GBDTConfig(n_trees=10, max_depth=5, n_candidates=32,
                           telemetry=True)
    m = repro.fit(xtr, ytr, cfg, jax.random.PRNGKey(0))
    rep = m.report
    s = rep.summarize()
    print(f"  round  loss    grad_norm  splits  best_gain")
    for r in (0, rep.n_rounds // 2, rep.n_rounds - 1):
        print(f"  {r:5d}  {float(rep.train_loss[r]):.4f}  "
              f"{float(rep.grad_norm[r]):9.2f}  "
              f"{int(rep.n_splits[r]):6d}  "
              f"{float(rep.best_gain_max[r]):9.2f}")
    print(f"  loss {s['train_loss']['first']:.4f} -> "
          f"{s['train_loss']['final']:.4f} over {s['n_rounds']} rounds, "
          f"{s['splits']['total']} splits realized")

    print("\n=== 3. Theorem 1: E[rank error] = 1/(k+1) ===")
    out = rank_error.fig2_experiment(seed=0, n=1024, ks=[4, 16, 64],
                                     trials=16)
    print(f"  {'k':>4} {'random':>8} {'quantile':>9} {'1/(k+1)':>8}")
    for k, r, q, t in zip(out["k"], out["random"], out["quantile"],
                          out["theory"]):
        print(f"  {k:4d} {r:8.4f} {q:9.4f} {t:8.4f}")
    print("  -> quantile binning is no better than random (the claim).")


if __name__ == "__main__":
    main()
