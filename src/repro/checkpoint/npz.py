"""Flat-path npz checkpointing with sharding-aware restore.

Pytrees are flattened to ``a/b/c``-keyed arrays inside a single .npz per
step.  On restore, arrays are device_put against the provided shardings
(pass the train-state sharding tree from the launcher to restore straight
into a sharded pjit state).  Writes are atomic (tmp + rename).
"""

from __future__ import annotations

import os
import re
import tempfile

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_key_str(k) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"#{k.idx}"
    return str(k)


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    flat = _flatten(tree)
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)
    return path


def restore_checkpoint(path: str, target, shardings=None):
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    jax.sharding.Sharding for direct sharded placement."""
    with np.load(path) as data:
        leaves_paths = jax.tree_util.tree_flatten_with_path(target)
        flat_target, treedef = jax.tree_util.tree_flatten(target)
        shard_flat = (jax.tree_util.tree_leaves(shardings)
                      if shardings is not None else [None] * len(flat_target))
        out = []
        for (path_k, leaf), sh in zip(leaves_paths[0], shard_flat):
            key = _SEP.join(_key_str(k) for k in path_k)
            if key not in data:
                raise KeyError(f"checkpoint missing {key!r}")
            arr = data[key]
            if arr.shape != tuple(leaf.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs "
                    f"target {tuple(leaf.shape)}")
            try:
                arr = arr.astype(leaf.dtype)
            except (ValueError, TypeError):
                # numpy stores ml_dtypes (bf16, fp8) as raw void; reinterpret
                import ml_dtypes
                arr = arr.view(np.dtype(leaf.dtype)) \
                    if arr.dtype.kind == "V" else arr.astype(
                        ml_dtypes.bfloat16).astype(leaf.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(leaves_paths[1], out)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.npz$", f))]
    return max(steps) if steps else None
