"""GBDT model checkpointing for serving.

A trained :class:`repro.core.boosting.GBDTModel` round-trips through one
.npz file: the stacked Forest arrays, the candidate grid (the bin edges
the binned predict path traverses on), the base score, and the
:class:`GBDTConfig` as a JSON string — everything ``predict`` needs, so
a serving process (``repro.launch.serve_gbdt``) restores a model with
no access to the training data or trainer.  Writes are atomic
(tmp + rename, same discipline as :mod:`repro.checkpoint.npz`) and the
round-trip is bit-exact: predictions from a reloaded model are
identical to the original (pinned by tests/test_predict_engine.py).
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile

import jax.numpy as jnp
import numpy as np

from ..core import boosting, tree as tree_lib

_SCHEMA = "repro.checkpoint.GBDTModel/v1"


def save_gbdt(path: str, model: boosting.GBDTModel) -> str:
    """Serialize a trained model to one .npz file (atomic write).

    Only the serving surface is saved — forest, candidates, base score,
    config.  Training telemetry (``report``) and wall-clock fields are
    deliberately dropped; they describe the fit, not the model.
    """
    cfg = dataclasses.asdict(model.config)
    payload = {
        "schema": np.array(_SCHEMA),
        "config_json": np.array(json.dumps(cfg)),
        "base_score": np.float64(model.base_score),
        "candidates": np.asarray(model.candidates),
        "forest/feature": np.asarray(model.forest.feature),
        "forest/threshold": np.asarray(model.forest.threshold),
        "forest/split_bin": np.asarray(model.forest.split_bin),
        "forest/leaf_value": np.asarray(model.forest.leaf_value),
    }
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **payload)
    os.replace(tmp, path)
    return path


def load_gbdt(path: str) -> boosting.GBDTModel:
    """Restore a model saved by :func:`save_gbdt`.

    Predictions from the restored model are bit-identical to the
    original: every array reloads with its exact dtype and the config
    round-trips through JSON (floats stored as Python floats survive
    exactly — json preserves the shortest round-trip representation).
    """
    with np.load(path) as data:
        schema = str(data["schema"])
        if schema != _SCHEMA:
            raise ValueError(
                f"unexpected checkpoint schema {schema!r} (want {_SCHEMA!r})")
        cfg = boosting.GBDTConfig(**json.loads(str(data["config_json"])))
        forest = tree_lib.Forest(
            feature=jnp.asarray(data["forest/feature"]),
            threshold=jnp.asarray(data["forest/threshold"]),
            split_bin=jnp.asarray(data["forest/split_bin"]),
            leaf_value=jnp.asarray(data["forest/leaf_value"]),
        )
        return boosting.GBDTModel(
            config=cfg,
            forest=forest,
            base_score=float(data["base_score"]),
            candidates=jnp.asarray(data["candidates"]),
        )
