"""Checkpoint substrate."""

from .gbdt import save_gbdt, load_gbdt
from .npz import save_checkpoint, restore_checkpoint, latest_step

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "save_gbdt", "load_gbdt"]
