"""Batched forest inference engine: level-synchronous traversal.

Training got its single-compile scan trainer and level-batched
histograms; this module gives *inference* the same treatment.  The old
predictor (``tree.forest_predict_raw``, now a deprecated shim) was a
sequential ``lax.scan`` over trees — ``n_trees`` dependent dispatch
chains of ``max_depth`` tiny gathers each, the opposite of how a
serving path should use the hardware.

Here the stacked :class:`repro.core.tree.Forest` — already a
struct-of-arrays ``(n_trees, 2^d - 1)`` heap — is traversed
**level-synchronously**: a chunk of ``C`` trees advances one depth
level per step, carrying an ``(n_rows, C)`` node-index matrix and doing
ONE fused gather + compare across all trees of the chunk
(:func:`repro.kernels.ops.traverse_chunk`; the `ref` backend is a vmap
over the per-tree descent, `packed` a complex64 record gather, `pallas`
a masked-select kernel).  A ``lax.scan`` over tree chunks keeps working
memory at O(rows x chunk) and the traversal compile count O(1) in
``n_trees`` — the chunk step's Python body traces once per compiled
predict regardless of forest size (``traverse_trace_count``, pinned by
tests/test_retrace.py), mirroring the trainer's round-step contract.

Exactness: within each chunk the per-tree leaf values are accumulated
onto the carry in tree order, so the ensemble sum is **bit-identical**
to the sequential per-tree scan it replaces (padding trees are
passthrough with leaf 0 — adding exact zeros).

The binned fast path (``binned=True``) traverses on int bin ids
(``bin <= split_bin``) instead of float thresholds.  Because recorded
thresholds ARE candidate-grid boundaries (``threshold =
candidates[feature, split_bin]``), binned routing is exact vs the raw
path on finite rows binned against the training grid.  NaN contract:
raw NaN compares False at every node and routes RIGHT; binned NaN sits
in the LAST bin (``bin_features``) and follows that bin's routing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from ..kernels.ops import TraverseSpec
from . import tree as tree_lib


# ---------------------------------------------------------------------------
# Traversal trace accounting — same convention as boosting.round_trace_count:
# the chunk step's Python body runs exactly once per trace of the
# surrounding jit, so this counter IS the lowering count of the
# traversal hot loop.  tests/test_retrace.py asserts it does not grow
# with n_trees.
# ---------------------------------------------------------------------------

# 25 won the 500x6 CPU chunk sweep (benchmarks/bench_predict.py): big
# enough to amortize the per-chunk scan step, small enough that the
# (rows, chunk) traversal temporaries stay cache-resident.
DEFAULT_TREE_CHUNK = 25

_traverse_traces = 0


def _bump_traverse_traces() -> None:
    global _traverse_traces
    _traverse_traces += 1


def traverse_trace_count() -> int:
    """How many times the traversal chunk step has been traced."""
    return _traverse_traces


def _forest_sum_impl(forest: tree_lib.Forest, values: jax.Array,
                     acc0: jax.Array, max_depth: int,
                     spec: TraverseSpec) -> jax.Array:
    """Chunk-scanned ensemble leaf-value sum (traced body, see module doc)."""
    t = forest.n_trees
    c = spec.tree_chunk
    pad = -t % c
    cmp = forest.split_bin if spec.binned else forest.threshold
    feat, leafv = forest.feature, forest.leaf_value
    if pad:
        # passthrough zero-leaf padding trees: every row descends the
        # all-left spine into leaf 0 and contributes an exact 0.0
        feat = jnp.pad(feat, ((0, pad), (0, 0)), constant_values=-1)
        cmp = jnp.pad(cmp, ((0, pad), (0, 0)),
                      constant_values=(2 ** 20 if spec.binned
                                       else np.inf))
        leafv = jnp.pad(leafv, ((0, pad), (0, 0)))
    n_chunks = (t + pad) // c
    chunks = (feat.reshape(n_chunks, c, -1),
              cmp.reshape(n_chunks, c, -1),
              leafv.reshape(n_chunks, c, -1))

    def chunk_step(acc, chunk):
        _bump_traverse_traces()
        fe, cm, lf = chunk
        vals = ops.traverse_chunk(values, fe, cm, lf, spec,
                                  max_depth=max_depth)   # (n, C)
        # accumulate in tree order: bit-identical to the per-tree scan
        for i in range(c):
            acc = acc + vals[:, i]
        return acc, None

    acc, _ = jax.lax.scan(chunk_step, acc0, chunks)
    return acc


@functools.partial(jax.jit, static_argnames=("max_depth", "spec"),
                   donate_argnums=(2,))
def _forest_sum(forest, values, acc0, *, max_depth: int,
                spec: TraverseSpec):
    return _forest_sum_impl(forest, values, acc0, max_depth, spec)


def margin(forest, values, base_score, learning_rate, *,
           max_depth: int, spec: TraverseSpec):
    """The single margin path for :meth:`GBDTModel.predict`: ``base +
    lr * ensemble_sum``, with the traversal jitted ONCE per (shapes,
    spec) — 'label' and 'proba' outputs route through this instead of
    rebuilding the ensemble sum per output mode.  The freshly-zeroed
    accumulator is donated into the chunk scan, which updates the carry
    buffer in place rather than double-buffering at the jit boundary.
    An empty ``(0, f)`` batch short-circuits to ``(0,)`` without
    tracing anything.

    The closing affine transform deliberately stays OUTSIDE the jit:
    fused, XLA contracts ``base + lr * sum`` into an FMA (1-ulp drift
    on CPU — ``optimization_barrier`` does not stop the LLVM-level
    contraction), whereas op-by-op it reproduces the historical eager
    ``base + lr * total`` bit-for-bit.  The two O(n) elementwise
    dispatches are noise next to the traversal.
    """
    values = jnp.asarray(values,
                         jnp.int32 if spec.binned else jnp.float32)
    n = values.shape[0]
    if n == 0:
        total = jnp.zeros((0,), jnp.float32)
    else:
        total = _forest_sum(forest, values, jnp.zeros((n,), jnp.float32),
                            max_depth=max_depth, spec=spec)
    return base_score + learning_rate * total


def forest_predict(forest: tree_lib.Forest, values: jax.Array, *,
                   max_depth: int, spec: TraverseSpec | None = None,
                   binned: bool = False, tree_chunk: int | None = None,
                   backend: str = "auto") -> jax.Array:
    """Unscaled ensemble sum over a stacked forest, batched across trees.

    Drop-in replacement for the deprecated per-tree-scan
    ``tree.forest_predict_raw`` (bit-identical output), with a binned
    mode the scan never had.  The caller applies learning rate and base
    score — or uses :func:`margin` / ``GBDTModel.predict`` which do.

    Args:
      values: (n, f) raw float32 features, or int bin ids (uint8/int32)
        when ``binned`` — e.g. from ``GBDTModel.bin_features``.
      spec: full :class:`TraverseSpec`; overrides the ``binned`` /
        ``tree_chunk`` / ``backend`` conveniences when given.

    Returns:
      (n,) float32 sum of per-tree leaf values; ``(0,)`` for an empty
      batch without tracing anything.
    """
    if spec is None:
        spec = TraverseSpec(tree_chunk=tree_chunk or DEFAULT_TREE_CHUNK,
                            binned=binned, backend=backend)
    spec = spec.resolved()            # pin 'auto' outside the trace
    values = jnp.asarray(values, jnp.int32 if spec.binned else jnp.float32)
    n = values.shape[0]
    if n == 0:
        return jnp.zeros((0,), jnp.float32)
    acc0 = jnp.zeros((n,), jnp.float32)
    return _forest_sum(forest, values, acc0, max_depth=max_depth,
                       spec=spec)
