"""Candidate split-point proposal strategies.

The paper's contribution is the ``random`` strategy (uniform sampling of
feature values) plus its distributed form (Algorithm 1: local sample →
AllReduce/all-gather → shared resample).  The baselines it is measured
against are the "data faithful" strategies: GK quantile summary
(XGBoost's unweighted limit), the weighted quantile sketch (XGBoost
proper), and fixed uniform-range bins (CatBoost-style).

All strategies return a dense ``(n_features, k)`` float32 array of sorted
candidate values; a feature with fewer distinct values than k simply
repeats values (binning collapses duplicates into empty bins, which is
harmless for split finding).
"""

from __future__ import annotations

import warnings
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from . import sketch

Strategy = Literal["random", "gk_quantile", "weighted_quantile",
                   "uniform_range", "exact"]

# Strategies that lower to pure jax ops, so the boosting trainers can
# re-propose *inside* a lax.scan round step.  The host-side strategies
# ('gk_quantile', 'exact') are x-only — their candidates are identical
# every round — so the trainers compute them once outside the scan.
TRACEABLE: tuple[str, ...] = ("random", "weighted_quantile",
                              "uniform_range")


# ---------------------------------------------------------------------------
# The paper's method: uniform random sampling (jit-able, O(n) per feature).
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k",))
def random_candidates(key: jax.Array, x: jax.Array, k: int) -> jax.Array:
    """Uniform random candidates for every feature.

    Args:
      key: PRNG key.
      x: (n, f) feature matrix.
      k: candidates per feature.

    Returns:
      (f, k) sorted candidates.
    """
    n, f = x.shape

    def per_feature(key, col):
        idx = jax.random.randint(key, (k,), 0, n)
        return jnp.sort(col[idx])

    keys = jax.random.split(key, f)
    return jax.vmap(per_feature)(keys, x.T)


@partial(jax.jit, static_argnames=("k",))
def random_candidates_local(key: jax.Array, x_local: jax.Array, k: int) -> jax.Array:
    """Per-worker local sampling done 'during data read' (Appendix 6.1)."""
    return random_candidates(key, x_local, k)


def resample_gathered(key: jax.Array, gathered: jax.Array, k: int) -> jax.Array:
    """Algorithm 1's post-AllReduce step: combine then resample to size k.

    Args:
      gathered: (workers, f, k) candidates from every worker
        (the all-gather result — identical on every worker).
      k: target candidates per feature.

    Returns:
      (f, k) sorted candidates — deterministic in ``key`` so every worker
      computes the *same* set without a second broadcast.
    """
    w, f, kk = gathered.shape
    pool = jnp.transpose(gathered, (1, 0, 2)).reshape(f, w * kk)

    def per_feature(key, row):
        idx = jax.random.randint(key, (k,), 0, row.shape[0])
        return jnp.sort(row[idx])

    keys = jax.random.split(key, f)
    return jax.vmap(per_feature)(keys, pool)


# ---------------------------------------------------------------------------
# Baselines ("data faithful").
# ---------------------------------------------------------------------------

def _pad_candidates(c: np.ndarray, k: int) -> np.ndarray:
    """Right-pad a (possibly empty) candidate row to length k.

    Degenerate features — constant columns, empty inputs — can yield
    zero candidates, where ``np.pad(..., mode='edge')`` raises; an
    all-zero row is harmless (binning collapses duplicate candidates
    into empty bins, so the feature is simply never split on).
    """
    c = np.asarray(c, dtype=np.float32)
    if len(c) >= k:
        return c[:k]
    if len(c) == 0:
        return np.zeros(k, dtype=np.float32)
    return np.pad(c, (0, k - len(c)), mode="edge")


def gk_quantile_candidates(x: np.ndarray, k: int) -> np.ndarray:
    """GK-summary candidates per feature (host-side; deliberately costly)."""
    x = np.asarray(x)
    out = np.empty((x.shape[1], k), dtype=np.float32)
    for j in range(x.shape[1]):
        out[j] = _pad_candidates(sketch.gk_candidates(x[:, j], k), k)
    return out


@partial(jax.jit, static_argnames=("k",))
def weighted_quantile_candidates(x: jax.Array, hess: jax.Array, k: int) -> jax.Array:
    """XGBoost weighted-quantile candidates; hessian-weighted."""
    return jax.vmap(lambda col: sketch.weighted_quantiles(col, hess, k))(x.T)


@partial(jax.jit, static_argnames=("k",))
def uniform_range_candidates(x: jax.Array, k: int) -> jax.Array:
    """CatBoost-style fixed bins: k evenly spaced points in [min, max]."""
    lo = jnp.min(x, axis=0)
    hi = jnp.max(x, axis=0)
    t = jnp.arange(1, k + 1) / (k + 1)
    return lo[:, None] + (hi - lo)[:, None] * t[None, :]


def exact_candidates(x: np.ndarray, k: int) -> np.ndarray:
    """All unique values, capped at k per feature (greedy exact baseline).

    With k >= number of unique values this reproduces the exact greedy
    algorithm; used for correctness tests on small data.
    """
    x = np.asarray(x)
    out = np.empty((x.shape[1], k), dtype=np.float32)
    for j in range(x.shape[1]):
        u = np.unique(x[:, j]).astype(np.float32)
        if len(u) >= k:
            idx = np.linspace(0, len(u) - 1, k).round().astype(int)
            out[j] = u[idx]
        else:
            out[j] = _pad_candidates(u, k)
    return out


# ---------------------------------------------------------------------------
# Unified front end.
# ---------------------------------------------------------------------------

def _in_traced_context(*operands) -> bool:
    """True when we are inside a jit/scan trace (any operand is a tracer,
    or the global trace state is dirty)."""
    if any(isinstance(a, jax.core.Tracer) for a in operands if a is not None):
        return True
    return not jax.core.trace_state_clean()


def propose(strategy: Strategy, x, k: int, *, key: jax.Array | None = None,
            hess: jax.Array | None = None,
            traced: bool | None = None) -> jnp.ndarray:
    """Unified proposal dispatch (distributed version in distributed.py).

    One entry point for both host code and jit-traced code: with
    ``traced=None`` (default) the jit context is auto-detected — any
    tracer operand, or a dirty trace state, selects the traced path,
    which restricts dispatch to the :data:`TRACEABLE` strategies (pure
    jax ops, safe inside a ``lax.scan`` round step).  Host-only
    strategies ('gk_quantile', 'exact') run numpy on concrete arrays and
    raise ``ValueError`` if requested while tracing.  Pass
    ``traced=True``/``False`` to force a path.

    Args:
      x: (n, f) feature matrix.
      k: candidates per feature.
      key: PRNG key (required for 'random').
      hess: (n,) hessian weights for 'weighted_quantile'; defaults to
        ones (the unweighted quantile sketch).

    Returns:
      (f, k) sorted float32 candidates.
    """
    if traced is None:
        traced = _in_traced_context(x, key, hess)
    if strategy == "random":
        if key is None:
            raise ValueError("random proposal needs a PRNG key")
        return random_candidates(key, jnp.asarray(x), k)
    if strategy == "weighted_quantile":
        if hess is None:
            hess = jnp.ones(x.shape[0], dtype=jnp.float32)
        return weighted_quantile_candidates(jnp.asarray(x), hess, k)
    if strategy == "uniform_range":
        return uniform_range_candidates(jnp.asarray(x), k)
    if strategy not in ("gk_quantile", "exact"):
        raise ValueError(f"unknown strategy {strategy!r}")
    if traced:
        raise ValueError(
            f"strategy {strategy!r} is host-only (numpy) and cannot run "
            f"under jit; propose outside the trace (TRACEABLE={TRACEABLE})")
    if strategy == "gk_quantile":
        return jnp.asarray(gk_quantile_candidates(np.asarray(x), k))
    return jnp.asarray(exact_candidates(np.asarray(x), k))


def propose_traced(strategy: Strategy, x: jax.Array, k: int,
                   key: jax.Array, hess: jax.Array) -> jax.Array:
    """Deprecated: use ``propose(strategy, x, k, key=key, hess=hess)`` —
    the unified dispatcher auto-detects jit context."""
    warnings.warn(
        "propose_traced is deprecated; use propose(strategy, x, k, "
        "key=key, hess=hess)", DeprecationWarning, stacklevel=2)
    return propose(strategy, x, k, key=key, hess=hess, traced=True)
