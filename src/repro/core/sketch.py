"""Quantile sketches — the "data faithful" baselines the paper argues against.

Two implementations:

* :class:`GKSummary` — a faithful Greenwald–Khanna (SIGMOD'01) streaming
  summary with the (v, g, Δ) tuple representation, INSERT and COMPRESS.
  This is the structure XGBoost's sketch generalises (with weights).
  Rank-query error is guaranteed ≤ εn.  It is intentionally host-side
  (numpy): the whole point of the paper is that this machinery costs more
  than random sampling, and we benchmark exactly that.

* :func:`weighted_quantiles` — the XGBoost-style weighted variant: split
  candidates at equal steps of cumulative *hessian* weight.  Used by the
  ``weighted_quantile`` proposal strategy (vectorised, jax).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


class GKSummary:
    """Greenwald–Khanna ε-approximate quantile summary.

    Maintains tuples (v_i, g_i, Δ_i) with  Σ_{j<=i} g_j - 1 <= rmin(v_i)
    and rmin(v_i) + Δ_i = rmax(v_i); the invariant g_i + Δ_i <= 2εn
    guarantees any rank query is answered within εn.
    """

    def __init__(self, eps: float):
        if not 0 < eps < 1:
            raise ValueError("eps must be in (0,1)")
        self.eps = eps
        self.n = 0
        # columns: value, g, delta
        self._v: list[float] = []
        self._g: list[int] = []
        self._d: list[int] = []

    def insert(self, value: float) -> None:
        import bisect
        i = bisect.bisect_left(self._v, value)
        if i == 0 or i == len(self._v):
            # new min or max: delta = 0
            self._v.insert(i, value)
            self._g.insert(i, 1)
            self._d.insert(i, 0)
        else:
            delta = int(np.floor(2 * self.eps * self.n)) if self.n else 0
            self._v.insert(i, value)
            self._g.insert(i, 1)
            self._d.insert(i, delta)
        self.n += 1
        # amortised compress
        if self.n % max(1, int(1.0 / (2 * self.eps))) == 0:
            self.compress()

    def extend(self, values) -> None:
        for v in np.asarray(values).ravel():
            self.insert(float(v))

    def compress(self) -> None:
        """Merge adjacent tuples while g_i + g_{i+1} + Δ_{i+1} <= 2εn."""
        if len(self._v) < 3:
            return
        cap = int(np.floor(2 * self.eps * self.n))
        v, g, d = self._v, self._g, self._d
        i = len(v) - 2
        while i >= 1:
            if g[i] + g[i + 1] + d[i + 1] <= cap:
                g[i + 1] += g[i]
                del v[i], g[i], d[i]
            i -= 1

    def query(self, phi: float) -> float:
        """Value whose rank is within εn of ceil(φ·n)."""
        if self.n == 0:
            raise ValueError("empty summary")
        target = max(1, int(np.ceil(phi * self.n)))
        bound = self.eps * self.n
        rmin = 0
        for i in range(len(self._v)):
            rmin += self._g[i]
            rmax = rmin + self._d[i]
            if target - rmin <= bound and rmax - target <= bound:
                return self._v[i]
        return self._v[-1]

    def candidates(self, k: int) -> np.ndarray:
        """k split candidates at evenly spaced quantiles (the XGBoost use).

        An empty summary has no quantiles: returns a zero-length array
        (the proposer pads it; ``query`` would raise).
        """
        if self.n == 0:
            return np.empty((0,), dtype=np.float32)
        self.compress()
        phis = (np.arange(1, k + 1)) / (k + 1)
        return np.array(sorted({self.query(p) for p in phis}), dtype=np.float32)

    def __len__(self) -> int:
        return len(self._v)


def gk_candidates(values: np.ndarray, k: int) -> np.ndarray:
    """Build a GK summary over ``values`` and query k candidates.

    eps is chosen as 1/k per the paper's Section 3.2 ("we expect to have
    as many bins as 1/eps").  Returns a sorted float32 array of <= k
    unique candidate values.
    """
    sk = GKSummary(eps=1.0 / max(2, k))
    sk.extend(values)
    return sk.candidates(k)


def weighted_quantiles(values: jax.Array, weights: jax.Array, k: int) -> jax.Array:
    """XGBoost-style weighted quantile candidates (vectorised).

    Candidates sit at equal steps of cumulative weight (XGBoost uses the
    hessian as the weight; eq. (8)-(9) of the XGBoost paper).

    Args:
      values: (n,) feature values.
      weights: (n,) nonnegative weights (e.g. hessians).
      k: number of candidates.

    Returns:
      (k,) sorted candidate values.
    """
    order = jnp.argsort(values)
    v = values[order]
    w = jnp.maximum(weights[order], 0.0)
    cw = jnp.cumsum(w)
    total = cw[-1]
    # k targets at equal weight steps (excluding 0 and total).
    targets = (jnp.arange(1, k + 1) / (k + 1)) * total
    idx = jnp.searchsorted(cw, targets, side="left")
    idx = jnp.clip(idx, 0, v.shape[0] - 1)
    return v[idx]
