"""Distributed GBDT training — the paper's Algorithm 1 on a JAX mesh.

Mapping from the paper's Rabit/AllReduce world to JAX:

  * worker        -> one slice of the ``data`` mesh axis (shard_map)
  * local sample at data read  -> random_candidates_local on the local shard
  * AllReduce(combine + resample) -> lax.all_gather over 'data' followed by
    a *shared-key* resample: every worker folds the same round key, so all
    workers compute the identical candidate set without a broadcast step.
  * histogram AllReduce -> lax.psum of the (node, feature, bin) panels
    inside the tree builder (the classic distributed-XGBoost pattern).
    With ``cfg.subtract`` on, only the HALF-width left-child panels are
    psum'd — each worker reconstructs the right children as
    ``parent - left`` from its (replicated) previous-level panel, so the
    per-level collective payload of tree growth halves (XGBoost's
    histogram-subtraction trick applied to the communication schedule).

The per-worker boosting loop is the same single-compile ``lax.scan``
round step as :func:`boosting.fit`: the round body (grad/hess ->
propose -> bin -> build_tree -> margin update, with its collectives)
is traced once and scanned over pre-split round keys, so the whole
n_trees-round training job is ONE compiled program per worker instead
of an unrolled O(n_trees) graph.  ``_worker_fit_reference`` keeps the
unrolled loop as the semantic oracle.

When ``n % n_workers != 0`` the driver pads the data with repeats of
the leading rows so every shard is equal-sized (static shapes), and
carries a per-row validity weight alongside: pad rows have their
grad/hess zeroed every round and drop out of the base-score and loss
reductions (``n_global`` is the TRUE row count), so the padded fit
computes exactly the statistics of the unpadded data — no duplicated
rows ever enter a psum.

The quantile baseline is also provided in distributed form (local sketch ->
all_gather -> merge), so Table-2-style comparisons run under the same
collective schedule.  With ``cfg.telemetry`` on, the scanned worker also
emits a per-round :class:`repro.obs.TrainReport` (loss / norms psum'd to
their global values, so the report is replicated across workers) and the
driver fills in the estimated per-round collective payload.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import binning, boosting, proposal, sketch, tree as tree_lib
from .. import compat, obs
from ..kernels import ops


def merge_quantile_gathered(gathered: jax.Array, k: int) -> jax.Array:
    """Distributed sketch merge: sort the union, take k evenly spaced.

    This is the classic quantile-summary merge (what XGBoost's AllReduce
    reducer does to per-worker GK summaries), specialised to equal-weight
    summaries.
    """
    w, f, kk = gathered.shape
    pool = jnp.sort(jnp.transpose(gathered, (1, 0, 2)).reshape(f, w * kk), axis=1)
    idx = jnp.floor((jnp.arange(1, k + 1) / (k + 1)) * (w * kk)).astype(jnp.int32)
    return pool[:, idx]


def _worker_propose(cfg: boosting.GBDTConfig, key_r, x_local, hess, w_local,
                    local_pool, axis: str):
    """One round's distributed proposal — traceable for every supported
    strategy, so it can live inside the scanned round step.  ``hess`` is
    already masked for pad rows; ``w_local`` is the validity weight (the
    unweighted-quantile limit uses it so pad rows carry no rank mass)."""
    if cfg.strategy == "random":
        gathered = lax.all_gather(local_pool, axis)              # (W, f, b)
        return proposal.resample_gathered(key_r, gathered, cfg.n_candidates)
    if cfg.strategy in ("weighted_quantile", "gk_quantile"):
        local_c = proposal.weighted_quantile_candidates(
            x_local,
            hess if cfg.strategy == "weighted_quantile" else w_local,
            cfg.n_candidates)
        gathered = lax.all_gather(local_c, axis)
        return merge_quantile_gathered(gathered, cfg.n_candidates)
    if cfg.strategy == "uniform_range":
        lo = lax.pmin(jnp.min(x_local, axis=0), axis)
        hi = lax.pmax(jnp.max(x_local, axis=0), axis)
        t = jnp.arange(1, cfg.n_candidates + 1) / (cfg.n_candidates + 1)
        return lo[:, None] + (hi - lo)[:, None] * t[None, :]
    raise ValueError(f"strategy {cfg.strategy!r} has no distributed form")


def _masked_grad_hess(margin, y_local, w_local, objective: str):
    """Per-row loss stats with pad rows zeroed: a weight-0 row contributes
    nothing to histograms, leaf values, or any psum downstream."""
    g, h = boosting.grad_hess(margin, y_local, objective)
    return g * w_local, h * w_local


def _worker_base_and_pool(x_local, y_local, w_local, key, *, cfg, axis,
                          n_global):
    """Shared preamble: global base score + 'data read' candidate pool.

    ``n_global`` is the TRUE global row count; pad rows are excluded
    from the label sum by ``w_local``, so the base score is exactly the
    unpadded one.
    """
    ysum = lax.psum(jnp.sum(y_local * w_local), axis)
    if cfg.objective == "logistic":
        p = jnp.clip(ysum / n_global, 1e-6, 1 - 1e-6)
        base = jnp.log(p / (1 - p))
    else:
        base = ysum / n_global

    # 'data read' stage: local candidate pool (Appendix 6.1).  Pad rows
    # may be sampled — they duplicate real leading rows, so the pool
    # still only contains observed feature values.
    widx = lax.axis_index(axis)
    local_pool = proposal.random_candidates_local(
        jax.random.fold_in(key, widx), x_local, cfg.n_candidates)
    return base, local_pool


def _worker_fit(x_local, y_local, w_local, key, *,
                cfg: boosting.GBDTConfig, axis: str, n_global: int,
                spec: ops.HistSpec):
    """Traced per-worker trainer; runs identically on every 'data' slice.

    One lax.scan over rounds — the round step (with its all_gather /
    psum collectives) compiles once regardless of cfg.n_trees.  Returns
    ``(forest, candidates, base, margin)`` plus a stacked
    :class:`repro.obs.TrainReport` when ``cfg.telemetry`` is on.
    """
    base, local_pool = _worker_base_and_pool(
        x_local, y_local, w_local, key, cfg=cfg, axis=axis,
        n_global=n_global)
    margin0 = jnp.full((x_local.shape[0],), base, jnp.float32)
    keys = boosting.round_keys(key, cfg.n_trees, offset=10_000)
    psum = lambda a: lax.psum(a, axis)                        # noqa: E731

    def grow(margin, bins, cands):
        g, h = _masked_grad_hess(margin, y_local, w_local, cfg.objective)
        built = tree_lib.build_tree(
            bins, jnp.stack([g, h], 1), cands,
            max_depth=cfg.max_depth, l2=cfg.l2,
            gamma=cfg.gamma, min_child_weight=cfg.min_child_weight,
            spec=spec, axis_name=axis, return_leaf_nodes=True,
            return_stats=cfg.telemetry)
        t, node = built[0], built[1]
        # growth already routed every local row to its leaf — gather the
        # leaf values directly instead of re-descending the tree
        margin = margin + cfg.learning_rate * t.leaf_value[node]
        rep = None
        if cfg.telemetry:
            # loss / norms psum to their global (pad-free) values, so
            # the report rows are replicated across workers
            rep = obs.round_report(margin=margin, y=y_local, g=g, h=h,
                                   objective=cfg.objective, stats=built[2],
                                   n_global=n_global, weight=w_local,
                                   psum=psum)
        return margin, t, rep

    if cfg.repropose_each_round:
        def round_step(margin, key_r):
            boosting._bump_round_traces()
            _, h = _masked_grad_hess(margin, y_local, w_local,
                                     cfg.objective)
            c = _worker_propose(cfg, key_r, x_local, h, w_local,
                                local_pool, axis)
            bins = binning.bin_features(x_local, c)
            margin, t, rep = grow(margin, bins, c)
            return margin, (t, c, rep)

        margin, (trees, cands, report) = lax.scan(round_step, margin0, keys)
        out = (tree_lib.Forest(*trees), cands, base, margin)
        return out + ((report,) if cfg.telemetry else ())

    _, h0 = _masked_grad_hess(margin0, y_local, w_local, cfg.objective)
    c0 = _worker_propose(cfg, keys[0], x_local, h0, w_local, local_pool,
                         axis)
    bins0 = binning.bin_features(x_local, c0)

    def round_step(margin, _key_r):
        boosting._bump_round_traces()
        margin, t, rep = grow(margin, bins0, c0)
        return margin, (t, rep)

    margin, (trees, report) = lax.scan(round_step, margin0, keys)
    out = (tree_lib.Forest(*trees), c0[None], base, margin)
    return out + ((report,) if cfg.telemetry else ())


def _worker_fit_reference(x_local, y_local, w_local, key, *,
                          cfg: boosting.GBDTConfig, axis: str,
                          n_global: int, spec: ops.HistSpec):
    """The original unrolled per-worker loop (O(n_trees) traced graph).
    Kept as the semantic oracle for the scanned worker (no telemetry)."""
    base, local_pool = _worker_base_and_pool(
        x_local, y_local, w_local, key, cfg=cfg, axis=axis,
        n_global=n_global)
    margin = jnp.full((x_local.shape[0],), base, jnp.float32)
    trees = []
    cands = []
    bins = None

    for r in range(cfg.n_trees):
        g, h = _masked_grad_hess(margin, y_local, w_local, cfg.objective)
        if cfg.repropose_each_round or r == 0:
            c = _worker_propose(cfg, jax.random.fold_in(key, 10_000 + r),
                                x_local, h, w_local, local_pool, axis)
            bins = binning.bin_features(x_local, c)
            cands.append(c)
        t = tree_lib.build_tree(
            bins, jnp.stack([g, h], 1), cands[-1],
            max_depth=cfg.max_depth, l2=cfg.l2,
            gamma=cfg.gamma, min_child_weight=cfg.min_child_weight,
            spec=spec, axis_name=axis)
        trees.append(t)
        margin = margin + cfg.learning_rate * tree_lib.predict_binned(
            t, bins, max_depth=cfg.max_depth)

    return (tree_lib.forest_from_trees(trees), jnp.stack(cands), base,
            margin)


def fit_distributed(x, y, cfg: boosting.GBDTConfig, mesh: Mesh,
                    key: jax.Array | None = None,
                    axis: str = "data",
                    reference: bool = False) -> boosting.GBDTModel:
    """Train a GBDT with rows sharded over ``axis`` of ``mesh``.

    Semantics match :func:`boosting.fit` up to the candidate sets (each
    worker samples locally, then the union is resampled — Algorithm 1).
    When ``n`` does not divide the worker count the data is padded with
    repeats of the leading rows for static shard shapes, but a per-row
    validity weight zeroes the pad rows' grad/hess and label mass, so
    base score, histograms, and leaf values are exactly those of the
    unpadded data.  ``reference=True`` runs the unrolled oracle loop
    instead of the scanned trainer (tests only).
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    n_true = x.shape[0]
    nw = mesh.shape[axis]
    valid = jnp.ones((n_true,), jnp.float32)
    if n_true % nw:
        pad = nw - n_true % nw
        # repeat leading rows so shard shapes stay static; their weight
        # is zero, so they never reach a psum'd statistic
        x = jnp.concatenate([x, x[:pad]], 0)
        y = jnp.concatenate([y, y[:pad]], 0)
        valid = jnp.concatenate([valid, jnp.zeros((pad,), jnp.float32)], 0)

    xs = jax.device_put(x, NamedSharding(mesh, P(axis, None)))
    ys = jax.device_put(y, NamedSharding(mesh, P(axis)))
    ws = jax.device_put(valid, NamedSharding(mesh, P(axis)))

    worker = _worker_fit_reference if reference else _worker_fit
    telemetry = cfg.telemetry and not reference
    fn = functools.partial(worker, cfg=cfg, axis=axis, n_global=n_true,
                           spec=cfg.hist_spec().resolved())
    out = jax.jit(compat.shard_map(
        fn, mesh=mesh,
        in_specs=(P(axis, None), P(axis), P(axis), P()),
        out_specs=(P(), P(), P(), P(axis)) + ((P(),) if telemetry else ()),
        check_vma=False,
    ))(xs, ys, ws, key)
    forest, cands, base, _margin = out[:4]

    report = None
    if telemetry:
        report = out[4]
        ag, ps = obs.collective_bytes_per_round(cfg, x.shape[1], nw)
        report = report._replace(all_gather_bytes=jnp.asarray(ag),
                                 psum_bytes=jnp.asarray(ps))
    return boosting.GBDTModel(cfg, forest, float(base), cands,
                              report=report)
