"""Distributed GBDT training — the paper's Algorithm 1 on a JAX mesh.

Mapping from the paper's Rabit/AllReduce world to JAX:

  * worker        -> one slice of the ``data`` mesh axis (shard_map)
  * local sample at data read  -> random_candidates_local on the local shard
  * AllReduce(combine + resample) -> lax.all_gather over 'data' followed by
    a *shared-key* resample: every worker folds the same round key, so all
    workers compute the identical candidate set without a broadcast step.
  * histogram AllReduce -> lax.psum of the (node, feature, bin) panels
    inside the tree builder (the classic distributed-XGBoost pattern).

The quantile baseline is also provided in distributed form (local sketch ->
all_gather -> merge), so Table-2-style comparisons run under the same
collective schedule.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import binning, boosting, proposal, sketch, tree as tree_lib


def merge_quantile_gathered(gathered: jax.Array, hess_hint: jax.Array | None,
                            k: int) -> jax.Array:
    """Distributed sketch merge: sort the union, take k evenly spaced.

    This is the classic quantile-summary merge (what XGBoost's AllReduce
    reducer does to per-worker GK summaries), specialised to equal-weight
    summaries.
    """
    w, f, kk = gathered.shape
    pool = jnp.sort(jnp.transpose(gathered, (1, 0, 2)).reshape(f, w * kk), axis=1)
    idx = jnp.floor((jnp.arange(1, k + 1) / (k + 1)) * (w * kk)).astype(jnp.int32)
    return pool[:, idx]


def _worker_fit(x_local, y_local, key, *, cfg: boosting.GBDTConfig,
                axis: str, n_global: int):
    """Traced per-worker trainer; runs identically on every 'data' slice."""
    psum = lambda a: lax.psum(a, axis)

    # global base score
    ysum = psum(jnp.sum(y_local))
    if cfg.objective == "logistic":
        p = jnp.clip(ysum / n_global, 1e-6, 1 - 1e-6)
        base = jnp.log(p / (1 - p))
    else:
        base = ysum / n_global

    # 'data read' stage: local candidate pool (Appendix 6.1)
    widx = lax.axis_index(axis)
    local_pool = proposal.random_candidates_local(
        jax.random.fold_in(key, widx), x_local, cfg.n_candidates)

    margin = jnp.full((x_local.shape[0],), base, jnp.float32)
    trees = []
    cands = []
    bins = None

    for r in range(cfg.n_trees):
        g, h = boosting.grad_hess(margin, y_local, cfg.objective)
        if cfg.repropose_each_round or r == 0:
            if cfg.strategy == "random":
                gathered = lax.all_gather(local_pool, axis)      # (W, f, b)
                c = proposal.resample_gathered(
                    jax.random.fold_in(key, 10_000 + r), gathered,
                    cfg.n_candidates)
            elif cfg.strategy in ("weighted_quantile", "gk_quantile"):
                local_c = proposal.weighted_quantile_candidates(
                    x_local,
                    h if cfg.strategy == "weighted_quantile"
                    else jnp.ones_like(h),
                    cfg.n_candidates)
                gathered = lax.all_gather(local_c, axis)
                c = merge_quantile_gathered(gathered, None, cfg.n_candidates)
            elif cfg.strategy == "uniform_range":
                lo = psum(jnp.zeros(())) * 0 + lax.pmin(
                    jnp.min(x_local, axis=0), axis)
                hi = lax.pmax(jnp.max(x_local, axis=0), axis)
                t = jnp.arange(1, cfg.n_candidates + 1) / (cfg.n_candidates + 1)
                c = lo[:, None] + (hi - lo)[:, None] * t[None, :]
            else:
                raise ValueError(
                    f"strategy {cfg.strategy!r} has no distributed form")
            bins = binning.bin_features(x_local, c)
            cands.append(c)

        t = tree_lib.build_tree(
            bins, jnp.stack([g, h], 1), cands[-1],
            max_depth=cfg.max_depth, nbins=cfg.nbins, l2=cfg.l2,
            gamma=cfg.gamma, min_child_weight=cfg.min_child_weight,
            backend=cfg.backend, axis_name=axis)
        trees.append(t)
        margin = margin + cfg.learning_rate * tree_lib.predict_binned(
            t, bins, max_depth=cfg.max_depth)

    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
    cands_arr = jnp.stack(cands)
    return stacked, cands_arr, base, margin


def fit_distributed(x, y, cfg: boosting.GBDTConfig, mesh: Mesh,
                    key: jax.Array | None = None,
                    axis: str = "data") -> boosting.GBDTModel:
    """Train a GBDT with rows sharded over ``axis`` of ``mesh``.

    Semantics match :func:`boosting.fit` up to the candidate sets (each
    worker samples locally, then the union is resampled — Algorithm 1).
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    n = x.shape[0]
    nw = mesh.shape[axis]
    if n % nw:
        pad = nw - n % nw
        # pad with repeats of the first rows; weight-neutral enough for
        # benchmarks, exact for n % nw == 0 (tests use divisible n)
        x = jnp.concatenate([x, x[:pad]], 0)
        y = jnp.concatenate([y, y[:pad]], 0)
        n = x.shape[0]

    xs = jax.device_put(x, NamedSharding(mesh, P(axis, None)))
    ys = jax.device_put(y, NamedSharding(mesh, P(axis)))

    fn = functools.partial(_worker_fit, cfg=cfg, axis=axis, n_global=n)
    stacked, cands, base, _margin = jax.jit(jax.shard_map(
        fn, mesh=mesh,
        in_specs=(P(axis, None), P(axis), P()),
        out_specs=(P(), P(), P(), P(axis)),
        check_vma=False,
    ))(xs, ys, key)

    trees = [jax.tree.map(lambda a, i=i: a[i], stacked)
             for i in range(cfg.n_trees)]
    cand_list = [cands[i] for i in range(cands.shape[0])]
    return boosting.GBDTModel(cfg, trees, float(base), cand_list)
