"""Distributed GBDT training — the paper's Algorithm 1 on a JAX mesh.

Mapping from the paper's Rabit/AllReduce world to JAX:

  * worker        -> one slice of the ``data`` mesh axis (shard_map)
  * local sample at data read  -> random_candidates_local on the local shard
  * AllReduce(combine + resample) -> lax.all_gather over 'data' followed by
    a *shared-key* resample: every worker folds the same round key, so all
    workers compute the identical candidate set without a broadcast step.
  * histogram AllReduce -> lax.psum of the (node, feature, bin) panels
    inside the tree builder (the classic distributed-XGBoost pattern).

The per-worker boosting loop is the same single-compile ``lax.scan``
round step as :func:`boosting.fit`: the round body (grad/hess ->
propose -> bin -> build_tree -> margin update, with its collectives)
is traced once and scanned over pre-split round keys, so the whole
n_trees-round training job is ONE compiled program per worker instead
of an unrolled O(n_trees) graph.  ``_worker_fit_reference`` keeps the
unrolled loop as the semantic oracle.

The quantile baseline is also provided in distributed form (local sketch ->
all_gather -> merge), so Table-2-style comparisons run under the same
collective schedule.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import binning, boosting, proposal, sketch, tree as tree_lib
from .. import compat
from ..kernels import ops


def merge_quantile_gathered(gathered: jax.Array, hess_hint: jax.Array | None,
                            k: int) -> jax.Array:
    """Distributed sketch merge: sort the union, take k evenly spaced.

    This is the classic quantile-summary merge (what XGBoost's AllReduce
    reducer does to per-worker GK summaries), specialised to equal-weight
    summaries.
    """
    w, f, kk = gathered.shape
    pool = jnp.sort(jnp.transpose(gathered, (1, 0, 2)).reshape(f, w * kk), axis=1)
    idx = jnp.floor((jnp.arange(1, k + 1) / (k + 1)) * (w * kk)).astype(jnp.int32)
    return pool[:, idx]


def _worker_propose(cfg: boosting.GBDTConfig, key_r, x_local, hess,
                    local_pool, axis: str):
    """One round's distributed proposal — traceable for every supported
    strategy, so it can live inside the scanned round step."""
    if cfg.strategy == "random":
        gathered = lax.all_gather(local_pool, axis)              # (W, f, b)
        return proposal.resample_gathered(key_r, gathered, cfg.n_candidates)
    if cfg.strategy in ("weighted_quantile", "gk_quantile"):
        local_c = proposal.weighted_quantile_candidates(
            x_local,
            hess if cfg.strategy == "weighted_quantile"
            else jnp.ones_like(hess),
            cfg.n_candidates)
        gathered = lax.all_gather(local_c, axis)
        return merge_quantile_gathered(gathered, None, cfg.n_candidates)
    if cfg.strategy == "uniform_range":
        lo = lax.pmin(jnp.min(x_local, axis=0), axis)
        hi = lax.pmax(jnp.max(x_local, axis=0), axis)
        t = jnp.arange(1, cfg.n_candidates + 1) / (cfg.n_candidates + 1)
        return lo[:, None] + (hi - lo)[:, None] * t[None, :]
    raise ValueError(f"strategy {cfg.strategy!r} has no distributed form")


def _worker_base_and_pool(x_local, y_local, key, *, cfg, axis, n_global):
    """Shared preamble: global base score + 'data read' candidate pool."""
    ysum = lax.psum(jnp.sum(y_local), axis)
    if cfg.objective == "logistic":
        p = jnp.clip(ysum / n_global, 1e-6, 1 - 1e-6)
        base = jnp.log(p / (1 - p))
    else:
        base = ysum / n_global

    # 'data read' stage: local candidate pool (Appendix 6.1)
    widx = lax.axis_index(axis)
    local_pool = proposal.random_candidates_local(
        jax.random.fold_in(key, widx), x_local, cfg.n_candidates)
    return base, local_pool


def _worker_fit(x_local, y_local, key, *, cfg: boosting.GBDTConfig,
                axis: str, n_global: int, spec: ops.HistSpec):
    """Traced per-worker trainer; runs identically on every 'data' slice.

    One lax.scan over rounds — the round step (with its all_gather /
    psum collectives) compiles once regardless of cfg.n_trees.
    """
    base, local_pool = _worker_base_and_pool(
        x_local, y_local, key, cfg=cfg, axis=axis, n_global=n_global)
    margin0 = jnp.full((x_local.shape[0],), base, jnp.float32)
    keys = boosting.round_keys(key, cfg.n_trees, offset=10_000)

    def grow(margin, bins, cands):
        g, h = boosting.grad_hess(margin, y_local, cfg.objective)
        t, node = tree_lib.build_tree(
            bins, jnp.stack([g, h], 1), cands,
            max_depth=cfg.max_depth, l2=cfg.l2,
            gamma=cfg.gamma, min_child_weight=cfg.min_child_weight,
            spec=spec, axis_name=axis, return_leaf_nodes=True)
        # growth already routed every local row to its leaf — gather the
        # leaf values directly instead of re-descending the tree
        margin = margin + cfg.learning_rate * t.leaf_value[node]
        return margin, t

    if cfg.repropose_each_round:
        def round_step(margin, key_r):
            boosting._bump_round_traces()
            _, h = boosting.grad_hess(margin, y_local, cfg.objective)
            c = _worker_propose(cfg, key_r, x_local, h, local_pool, axis)
            bins = binning.bin_features(x_local, c)
            margin, t = grow(margin, bins, c)
            return margin, (t, c)

        margin, (trees, cands) = lax.scan(round_step, margin0, keys)
        return tree_lib.Forest(*trees), cands, base, margin

    _, h0 = boosting.grad_hess(margin0, y_local, cfg.objective)
    c0 = _worker_propose(cfg, keys[0], x_local, h0, local_pool, axis)
    bins0 = binning.bin_features(x_local, c0)

    def round_step(margin, _key_r):
        boosting._bump_round_traces()
        margin, t = grow(margin, bins0, c0)
        return margin, t

    margin, trees = lax.scan(round_step, margin0, keys)
    return tree_lib.Forest(*trees), c0[None], base, margin


def _worker_fit_reference(x_local, y_local, key, *,
                          cfg: boosting.GBDTConfig, axis: str,
                          n_global: int, spec: ops.HistSpec):
    """The original unrolled per-worker loop (O(n_trees) traced graph).
    Kept as the semantic oracle for the scanned worker."""
    base, local_pool = _worker_base_and_pool(
        x_local, y_local, key, cfg=cfg, axis=axis, n_global=n_global)
    margin = jnp.full((x_local.shape[0],), base, jnp.float32)
    trees = []
    cands = []
    bins = None

    for r in range(cfg.n_trees):
        g, h = boosting.grad_hess(margin, y_local, cfg.objective)
        if cfg.repropose_each_round or r == 0:
            c = _worker_propose(cfg, jax.random.fold_in(key, 10_000 + r),
                                x_local, h, local_pool, axis)
            bins = binning.bin_features(x_local, c)
            cands.append(c)
        t = tree_lib.build_tree(
            bins, jnp.stack([g, h], 1), cands[-1],
            max_depth=cfg.max_depth, l2=cfg.l2,
            gamma=cfg.gamma, min_child_weight=cfg.min_child_weight,
            spec=spec, axis_name=axis)
        trees.append(t)
        margin = margin + cfg.learning_rate * tree_lib.predict_binned(
            t, bins, max_depth=cfg.max_depth)

    return (tree_lib.forest_from_trees(trees), jnp.stack(cands), base,
            margin)


def fit_distributed(x, y, cfg: boosting.GBDTConfig, mesh: Mesh,
                    key: jax.Array | None = None,
                    axis: str = "data",
                    reference: bool = False) -> boosting.GBDTModel:
    """Train a GBDT with rows sharded over ``axis`` of ``mesh``.

    Semantics match :func:`boosting.fit` up to the candidate sets (each
    worker samples locally, then the union is resampled — Algorithm 1).
    ``reference=True`` runs the unrolled oracle loop instead of the
    scanned trainer (tests only).
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    n = x.shape[0]
    nw = mesh.shape[axis]
    if n % nw:
        pad = nw - n % nw
        # pad with repeats of the first rows; weight-neutral enough for
        # benchmarks, exact for n % nw == 0 (tests use divisible n)
        x = jnp.concatenate([x, x[:pad]], 0)
        y = jnp.concatenate([y, y[:pad]], 0)
        n = x.shape[0]

    xs = jax.device_put(x, NamedSharding(mesh, P(axis, None)))
    ys = jax.device_put(y, NamedSharding(mesh, P(axis)))

    worker = _worker_fit_reference if reference else _worker_fit
    fn = functools.partial(worker, cfg=cfg, axis=axis, n_global=n,
                           spec=cfg.hist_spec().resolved())
    forest, cands, base, _margin = jax.jit(compat.shard_map(
        fn, mesh=mesh,
        in_specs=(P(axis, None), P(axis), P()),
        out_specs=(P(), P(), P(), P(axis)),
        check_vma=False,
    ))(xs, ys, key)

    return boosting.GBDTModel(cfg, forest, float(base), cands)
