"""Core library: the paper's contribution.

Random split-point sampling for distributed decision-tree building
(Kumar & Edakunni 2021), plus the quantile-sketch baselines it is
measured against, a binned level-wise tree builder, a GBDT trainer, and
the shard_map distributed form of the paper's Algorithm 1.
"""

from . import binning, boosting, distributed, proposal, rank_error, sketch, tree

__all__ = ["binning", "boosting", "distributed", "proposal", "rank_error",
           "sketch", "tree"]
