"""Level-wise decision-tree growth on binned features.

TPU-native adaptation of XGBoost's approximate tree builder: instead of a
host-side node queue we grow a *complete* binary tree of static depth.
Level d has 2^d frontier nodes; every row carries a level-local node id.
Nodes that should not split (gain <= 0, min_child_weight violated) become
"passthrough" nodes: every row goes LEFT, the right child is empty
(G = H = 0 -> weight 0).  This wastes a bounded amount of compute in
exchange for fully static shapes — the standard TPU trade.

Heap layout (0-based): inner node i has children 2i+1 / 2i+2; level d
occupies indices [2^d - 1, 2^(d+1) - 2]; leaves are the 2^max_depth
level-(max_depth) nodes.

Split semantics (consistent with binning.py):
  row goes left  <=>  bin_id <= split_bin  <=>  x <= threshold
where threshold = candidates[feature, split_bin].
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from ..kernels.ops import HistSpec


class Tree(NamedTuple):
    """A single fitted tree (all arrays static-shaped)."""
    feature: jax.Array     # (2^depth - 1,) int32; -1 = passthrough
    split_bin: jax.Array   # (2^depth - 1,) int32; nbins-1 for passthrough
    threshold: jax.Array   # (2^depth - 1,) float32; +inf for passthrough
    leaf_value: jax.Array  # (2^depth,) float32


class TreeStats(NamedTuple):
    """Per-tree growth telemetry (all 0-d arrays, scan-stackable).

    Derived from the same (psum'd, in the distributed trainer) gain
    panel the splits themselves come from — plus the local row panel for
    the update count — so it is replicated across workers (the trainers
    psum ``hist_updates`` to its cluster-wide value) and adding it
    cannot change the grown tree.
    """
    n_splits: jax.Array     # () int32 — realized (gain > 0) splits
    gain_sum: jax.Array     # () float32 — sum of realized split gains
    gain_max: jax.Array     # () float32 — largest realized gain (0 if none)
    hist_updates: jax.Array  # () float32 — scatter updates issued for the
    #                          tree's histograms: sum over levels of
    #                          (rows actually scattered) * n_features.
    #                          Direct growth scatters every row at every
    #                          level; subtraction growth only the rows
    #                          routed LEFT.  float32 (telemetry — exact
    #                          below 2^24 updates per tree)


class Forest(NamedTuple):
    """A boosted ensemble as a struct-of-arrays: every field of Tree
    stacked along a leading round axis.  Static-shaped in (n_trees,
    max_depth), so it can be the per-round output of a ``lax.scan`` and
    the input of a single-compile vectorized predictor."""
    feature: jax.Array     # (T, 2^depth - 1) int32
    split_bin: jax.Array   # (T, 2^depth - 1) int32
    threshold: jax.Array   # (T, 2^depth - 1) float32
    leaf_value: jax.Array  # (T, 2^depth) float32

    @property
    def n_trees(self) -> int:
        return self.feature.shape[0]


def forest_from_trees(trees: list[Tree]) -> Forest:
    """Stack a Python list of trees (the reference-loop output)."""
    return Forest(*(jnp.stack(a) for a in zip(*trees)))


def forest_trees(forest: Forest) -> list[Tree]:
    """Per-tree views of a forest (host-side convenience/back-compat)."""
    return [Tree(*(a[i] for a in forest)) for i in range(forest.n_trees)]


def _level_slice(depth: int) -> slice:
    return slice(2 ** depth - 1, 2 ** (depth + 1) - 1)


@functools.partial(jax.jit, static_argnames=(
    "max_depth", "nbins", "l2", "gamma", "min_child_weight", "backend",
    "spec", "axis_name", "return_leaf_nodes", "return_stats"))
def build_tree(bins: jax.Array, gh: jax.Array, candidates: jax.Array, *,
               max_depth: int, nbins: int | None = None, l2: float = 1.0,
               gamma: float = 0.0, min_child_weight: float = 1e-6,
               backend: str = "auto",
               spec: HistSpec | None = None,
               axis_name: str | None = None,
               return_leaf_nodes: bool = False,
               return_stats: bool = False):
    """Grow one tree on binned data.

    The level loop is a ``lax.scan`` over a *uniform* frontier of
    ``F = 2^(max_depth-1)`` nodes: every level's histogram has the same
    static shape, so ONE compiled scatter (or Pallas launch) serves all
    levels instead of one program per depth.  At depth ``d < max_depth-1``
    node ids only occupy ``[0, 2^d)``; the unpopulated tail has an
    all-zero histogram, fails ``min_child_weight`` at every bin, and
    falls out as a passthrough — exactly the semantics the complete-tree
    layout already gives empty nodes, so the widened frontier is
    bit-exact vs the per-depth loop (same rows hit the same buckets in
    the same order).

    With ``spec.subtract`` set the scan instead runs histogram-
    subtraction growth (the classic trick of XGBoost/LightGBM, adapted
    to the uniform frontier): each level scatters only the rows routed
    LEFT, keyed by the parent id, into a HALF-width panel of
    ``F/2`` parent buckets; the right-child histograms are reconstructed
    as ``parent - left`` from the previous level's composed panel, which
    rides the scan carry.  Level 0 falls out of the same program — every
    row has child id 0 (even), so the "left" scatter is the full root
    histogram.  Unpopulated odd nodes are re-zeroed from a static
    populated-width mask (otherwise ``0 - left`` would leak the root's
    negation down the all-right spine of the carry).  In the distributed
    trainer only the half panel enters the per-level ``lax.psum`` —
    the collective payload of tree growth halves.  Float subtraction
    re-associates the right-child sums, so subtraction trees are only
    *tree-for-tree* pinned against the ``subtract=False`` oracles on
    fixed workloads rather than histogram-bit-exact (see README
    "Architecture").

    Args:
      bins: (n, f) int32 bin ids in [0, nbins).
      gh: (n, 2) grad/hess panel for the current boosting round.
      candidates: (f, k) candidate values (k = nbins - 1); used only to
        record raw thresholds for inference on unbinned data.
      nbins, backend: legacy kwargs; superseded by ``spec``.  Exactly
        one of ``spec`` / ``nbins`` must be provided.
      spec: :class:`HistSpec` describing the histogram workload.  Its
        ``n_nodes`` must cover the frontier (``>= 2^(max_depth-1)``).
      axis_name: if set, every histogram is lax.psum'd over this mesh
        axis (distributed-XGBoost histogram AllReduce inside shard_map);
        None = single host.
      return_leaf_nodes: also return each row's final leaf id.  Growth
        already routes every row to its leaf, so the scanned boosting
        trainers read the margin update as ``leaf_value[node]`` instead
        of re-descending the tree with predict_binned.
      return_stats: also return a :class:`TreeStats` (realized split
        count + gain summary) computed from the per-level gain panels.
        Static flag: the telemetry-off graph is unchanged.

    Returns:
      A :class:`Tree`, extended to ``(Tree, node)`` when
      ``return_leaf_nodes`` is set and further to ``(..., stats)`` when
      ``return_stats`` is set (``node`` is the (n,) int32 leaf
      assignment, ``stats`` the :class:`TreeStats`).
    """
    frontier = 2 ** max(max_depth - 1, 0)
    if spec is None:
        if nbins is None:
            raise TypeError("build_tree needs either spec= or nbins=")
        spec = HistSpec(n_nodes=frontier, nbins=nbins, n_levels=1,
                        backend=backend)
    else:
        if nbins is not None and nbins != spec.nbins:
            raise ValueError(
                f"nbins={nbins} conflicts with spec.nbins={spec.nbins}")
        if spec.n_nodes < frontier:
            raise ValueError(
                f"spec.n_nodes={spec.n_nodes} < frontier {frontier} "
                f"for max_depth={max_depth}")
    nbins = spec.nbins
    lspec = spec.with_levels(1)        # one scan step = one level

    psum = (None if axis_name is None
            else lambda a: jax.lax.psum(a, axis_name))
    n, f = bins.shape
    n_inner = 2 ** max_depth - 1
    n_leaves = 2 ** max_depth

    def split_and_route(hist, node, upd):
        """Shared tail of a level step: pick splits from the (already
        psum'd / composed) frontier panel and route rows one level down.
        ``upd`` is the level's scatter-update count (stats only)."""
        gains, sbins = ops.split_gain(hist, l2=l2, gamma=gamma,
                                      min_child_weight=min_child_weight,
                                      backend=lspec.backend)  # (nodes, f)
        gains = gains[:frontier]
        sbins = sbins[:frontier]
        best_f = jnp.argmax(gains, axis=1).astype(jnp.int32)  # (nodes,)
        best_gain = jnp.take_along_axis(gains, best_f[:, None], 1)[:, 0]
        best_s = jnp.take_along_axis(sbins, best_f[:, None], 1)[:, 0]

        do_split = best_gain > 0.0
        lvl_feature = jnp.where(do_split, best_f, -1)
        lvl_sbin = jnp.where(do_split, best_s, nbins - 1)
        lvl_thresh = jnp.where(
            do_split,
            candidates[lvl_feature.clip(0),
                       lvl_sbin.clip(0, candidates.shape[1] - 1)],
            jnp.inf)

        # route rows: left (2*node) if bin <= s else right (2*node + 1)
        row_bin = jnp.take_along_axis(
            bins, lvl_feature.clip(0)[node][:, None], axis=1)[:, 0]
        go_left = row_bin <= lvl_sbin[node]
        node = node * 2 + jnp.where(go_left, 0, 1)
        ys = (lvl_feature, lvl_sbin, lvl_thresh)
        if return_stats:
            # unpopulated frontier tail nodes have all-zero histograms
            # and never split, so summing the full frontier is exact
            realized = jnp.where(do_split, best_gain, 0.0)
            ys += ((jnp.sum(do_split.astype(jnp.int32)),
                    jnp.sum(realized), jnp.max(realized), upd),)
        return node, ys

    def level_step(node, _):
        # (n_nodes, f, nbins, 2); same shape every level — one program
        hist = ops.hist_levels(bins, node[None], gh, lspec)[0]
        if psum is not None:
            hist = psum(hist)
        # direct growth scatters every row at every level
        return split_and_route(hist, node, jnp.float32(n * f))

    half = max(frontier // 2, 1)
    sspec = dataclasses.replace(lspec, n_nodes=half)  # parent-keyed panel

    def level_step_subtract(carry, populated):
        node, prev = carry
        # half-width panel: LEFT-routed (even child id) rows only, keyed
        # by parent id — in the distributed trainer this halved panel is
        # all that crosses the mesh
        left = ops.hist_levels(bins, node[None], gh, sspec)[0]
        if psum is not None:
            left = psum(left)
        if frontier == 1:
            hist = left                     # single-node level: root hist
        else:
            # interleave [left[p], prev[p] - left[p]] -> child 2p, 2p+1;
            # re-zero unpopulated nodes so the carried panel stays the
            # true level histogram (prev=0 minus a stale left would leak
            # garbage down the all-right spine)
            hist = jnp.stack([left, prev[:half] - left], axis=1)
            hist = hist.reshape(frontier, f, nbins, 2)
            hist = jnp.where(populated[:, None, None, None], hist, 0.0)
        upd = jnp.sum((node % 2 == 0).astype(jnp.float32)) * f
        node, ys = split_and_route(hist, node, upd)
        return (node, hist), ys

    stats = TreeStats(jnp.int32(0), jnp.float32(0.0), jnp.float32(0.0),
                      jnp.float32(0.0))
    node = jnp.zeros((n,), jnp.int32)          # level-local node id
    if max_depth > 0:
        if spec.subtract:
            # populated[d, m] <=> node id m exists at depth d
            populated = (jnp.arange(frontier)[None, :]
                         < (2 ** jnp.arange(max_depth))[:, None])
            prev0 = jnp.zeros((frontier, f, nbins, 2), jnp.float32)
            (node, _), ys = jax.lax.scan(level_step_subtract,
                                         (node, prev0), populated)
        else:
            node, ys = jax.lax.scan(level_step, node, None,
                                    length=max_depth)
        if return_stats:
            feats, sbins_l, threshs, (ns_l, gs_l, gm_l, up_l) = ys
            stats = TreeStats(jnp.sum(ns_l).astype(jnp.int32),
                              jnp.sum(gs_l).astype(jnp.float32),
                              jnp.max(gm_l).astype(jnp.float32),
                              jnp.sum(up_l).astype(jnp.float32))
        else:
            feats, sbins_l, threshs = ys

    feature = jnp.full((n_inner,), -1, jnp.int32)
    split_bin = jnp.full((n_inner,), nbins - 1, jnp.int32)
    threshold = jnp.full((n_inner,), jnp.inf, jnp.float32)
    for depth in range(max_depth):
        sl = _level_slice(depth)
        w = 2 ** depth                 # populated prefix of the frontier
        feature = feature.at[sl].set(feats[depth, :w])
        split_bin = split_bin.at[sl].set(sbins_l[depth, :w])
        threshold = threshold.at[sl].set(threshs[depth, :w])

    # leaf values from final-level grad/hess totals; grad/hess packed
    # into one complex64 scatter (bit-exact: lanes add independently,
    # same row order) — ~1.3x faster than the 2-wide segment_sum on CPU
    z = jax.lax.complex(gh[:, 0].astype(jnp.float32),
                        gh[:, 1].astype(jnp.float32))
    seg_z = jnp.zeros((n_leaves,), jnp.complex64).at[node].add(z)
    seg = jnp.stack([seg_z.real, seg_z.imag], -1)
    if psum is not None:
        seg = psum(seg)
    leaf_value = -seg[:, 0] / (seg[:, 1] + l2)
    tree = Tree(feature, split_bin, threshold,
                leaf_value.astype(jnp.float32))
    out = (tree,)
    if return_leaf_nodes:
        out += (node,)
    if return_stats:
        out += (stats,)
    return out if len(out) > 1 else tree


def _descend_binned(tree: Tree, bins: jax.Array, max_depth: int) -> jax.Array:
    n = bins.shape[0]
    node = jnp.zeros((n,), jnp.int32)          # level-local id
    for depth in range(max_depth):
        heap = (2 ** depth - 1) + node
        fidx = tree.feature[heap]
        sbin = tree.split_bin[heap]
        row_bin = jnp.take_along_axis(bins, fidx.clip(0)[:, None], 1)[:, 0]
        go_left = row_bin <= sbin
        node = node * 2 + jnp.where(go_left, 0, 1)
    return tree.leaf_value[node]


def _descend_raw(tree: Tree, x: jax.Array, max_depth: int) -> jax.Array:
    n = x.shape[0]
    node = jnp.zeros((n,), jnp.int32)
    for depth in range(max_depth):
        heap = (2 ** depth - 1) + node
        fidx = tree.feature[heap]
        thr = tree.threshold[heap]
        xv = jnp.take_along_axis(x, fidx.clip(0)[:, None], 1)[:, 0]
        go_left = xv <= thr
        node = node * 2 + jnp.where(go_left, 0, 1)
    return tree.leaf_value[node]


@functools.partial(jax.jit, static_argnames=("max_depth",))
def predict_binned(tree: Tree, bins: jax.Array, *, max_depth: int) -> jax.Array:
    """Evaluate one tree on binned features; returns (n,) leaf values."""
    return _descend_binned(tree, bins, max_depth)


@functools.partial(jax.jit, static_argnames=("max_depth",))
def predict_raw(tree: Tree, x: jax.Array, *, max_depth: int) -> jax.Array:
    """Evaluate one tree on raw features (x <= threshold goes left)."""
    return _descend_raw(tree, x, max_depth)


@functools.partial(jax.jit, static_argnames=("max_depth",))
def _forest_predict_scan(forest: Forest, x: jax.Array, *,
                         max_depth: int) -> jax.Array:
    """Sequential per-tree scan ensemble sum — the ORIGINAL predictor,
    kept as the semantic oracle and bench baseline for the batched
    level-synchronous engine (:func:`repro.core.predict.forest_predict`,
    bit-identical output).  One compile for any n_trees, O(n) working
    memory, but n_trees dependent dispatch chains — not the fast path.

    Returns the *unscaled* ensemble sum; the caller applies learning
    rate and base score.
    """
    def body(acc, t):
        return acc + _descend_raw(Tree(*t), x, max_depth), None

    acc0 = jnp.zeros((x.shape[0],), jnp.float32)
    acc, _ = jax.lax.scan(body, acc0, forest)
    return acc


def forest_predict_raw(forest: Forest, x: jax.Array, *,
                       max_depth: int) -> jax.Array:
    """Deprecated: use :func:`repro.core.predict.forest_predict`, the
    batched level-synchronous engine (bit-identical, much faster)."""
    warnings.warn(
        "forest_predict_raw (per-tree scan) is deprecated; use "
        "repro.core.predict.forest_predict (batched level-synchronous "
        "traversal, bit-identical output)",
        DeprecationWarning, stacklevel=2)
    return _forest_predict_scan(forest, x, max_depth=max_depth)


@functools.partial(jax.jit, static_argnames=("max_depth",))
def forest_predict_binned(forest: Forest, bins: jax.Array, *,
                          max_depth: int) -> jax.Array:
    """As :func:`forest_predict_raw` but on pre-binned features."""
    def body(acc, t):
        return acc + _descend_binned(Tree(*t), bins, max_depth), None

    acc0 = jnp.zeros((bins.shape[0],), jnp.float32)
    acc, _ = jax.lax.scan(body, acc0, forest)
    return acc
