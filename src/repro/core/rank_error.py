"""Theorem 1 machinery: expected rank error of candidate-split subsets.

The paper's central theoretical object: given ``n`` sorted feature values
and an (unknown) tree objective ``f`` over split positions, a candidate
subset ``S`` of size ``k`` incurs *rank error*

    R(S, X) = rank (under f) of the best element of S,

so R = 0 when S contains the argmax of f.  Theorem 1: for S uniform
without replacement, ``E[R] = (n - k) / (k + 1)``; normalised by the worst
case (n - k) this is ``1 / (k + 1)``.

This module provides the closed forms, Monte-Carlo estimators for any
subset-selection strategy (random / quantile binning / ...), and the
machinery behind Fig. 2 of the paper.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def expected_rank_error(n: int, k: int) -> float:
    """Closed form of Theorem 1: E[R] = (n - k) / (k + 1)."""
    if not 0 < k <= n:
        raise ValueError(f"need 0 < k <= n, got n={n} k={k}")
    return (n - k) / (k + 1)


def normalized_rank_error(n: int, k: int) -> float:
    """Eq. (6): E = E[R] / (n - k) = 1 / (k + 1)."""
    if k >= n:
        return 0.0
    return expected_rank_error(n, k) / (n - k)


def rank_error_of_subset(f_values: jax.Array, subset_idx: jax.Array) -> jax.Array:
    """Rank error R(S, X) for one subset.

    Args:
      f_values: (n,) objective value at every split position.
      subset_idx: (k,) integer indices into ``f_values`` forming S.

    Returns:
      Scalar int: the 0-based rank (under descending f) of the best
      element of S.  0 means S contains the global argmax.
    """
    # rank[i] = number of positions with f strictly greater than f[i]
    order = jnp.argsort(-f_values)          # positions sorted best-first
    ranks = jnp.argsort(order)              # rank of each position
    best_in_s = subset_idx[jnp.argmax(f_values[subset_idx])]
    return ranks[best_in_s]


@partial(jax.jit, static_argnames=("k", "trials"))
def mc_rank_error_random(key: jax.Array, f_values: jax.Array, k: int,
                         trials: int = 256) -> jax.Array:
    """Monte-Carlo E[R] for uniform random subsets of size k."""
    n = f_values.shape[0]

    def one(key):
        idx = jax.random.choice(key, n, shape=(k,), replace=False)
        return rank_error_of_subset(f_values, idx)

    errs = jax.vmap(one)(jax.random.split(key, trials))
    return jnp.mean(errs.astype(jnp.float32))


def rank_error_of_binning(f_values: np.ndarray, bin_edges_idx: np.ndarray) -> int:
    """Rank error when S = bin representatives (deterministic binning).

    ``bin_edges_idx`` are the indices (into the sorted data) chosen as the
    bin representatives by a quantile-sketch strategy.
    """
    f = np.asarray(f_values)
    order = np.argsort(-f)
    ranks = np.empty_like(order)
    ranks[order] = np.arange(len(f))
    best = bin_edges_idx[np.argmax(f[bin_edges_idx])]
    return int(ranks[best])


def smooth_random_objective(key: jax.Array, n: int, roughness: int = 8) -> jax.Array:
    """A random smooth objective over split positions (as in Fig. 2).

    Sum of a few random sinusoids — smooth enough that quantile binning
    *could* help if data-faithfulness helped, rough enough to have a
    non-trivial argmax.
    """
    ks = jax.random.split(key, 3)
    t = jnp.linspace(0.0, 1.0, n)
    freqs = jax.random.uniform(ks[0], (roughness,), minval=0.5, maxval=6.0)
    phases = jax.random.uniform(ks[1], (roughness,), minval=0.0, maxval=2 * jnp.pi)
    amps = jax.random.uniform(ks[2], (roughness,), minval=0.2, maxval=1.0)
    return jnp.sum(amps[:, None] * jnp.sin(2 * jnp.pi * freqs[:, None] * t[None, :]
                                           + phases[:, None]), axis=0)


def fig2_experiment(seed: int, n: int, ks: list[int], trials: int = 64) -> dict:
    """Reproduce Fig. 2: mean normalised rank error vs k.

    For each subset size k, compare (a) uniform random selection with
    (b) deterministic equi-rank binning (the unweighted GK limit: bin
    representatives at every n/k-th rank) on random smooth objectives.

    Returns dict with 'k', 'random', 'quantile', 'theory' arrays of the
    normalised error E = E[R]/(n-k).
    """
    key = jax.random.PRNGKey(seed)
    out = {"k": list(ks), "random": [], "quantile": [], "theory": []}
    for k in ks:
        kk = jax.random.fold_in(key, k)
        rand_errs, quant_errs = [], []
        for t in range(trials):
            kt = jax.random.fold_in(kk, t)
            f = smooth_random_objective(kt, n)
            rand_errs.append(float(mc_rank_error_random(kt, f, k, trials=8)))
            # Deterministic equi-rank bins: representative = right edge of
            # each of the k equal-population buckets (the epsilon-approx
            # quantile answer for uniformly weighted data).
            reps = np.floor((np.arange(1, k + 1) * n) / k).astype(int) - 1
            quant_errs.append(rank_error_of_binning(np.asarray(f), reps))
        out["random"].append(float(np.mean(rand_errs)) / (n - k))
        out["quantile"].append(float(np.mean(quant_errs)) / (n - k))
        out["theory"].append(normalized_rank_error(n, k))
    return out
