"""Gradient-boosted decision trees over the binned tree builder.

Single-host trainer (the distributed shard_map trainer lives in
distributed.py and reuses the same scanned round step).  Mirrors the
paper's experimental setup: proposal strategy is pluggable per-round
('random' = the paper; 'gk_quantile' / 'weighted_quantile' /
'uniform_range' = the data-faithful baselines; 'exact' = greedy).

The hot loop is a single-compile ``lax.scan`` over boosting rounds: one
round step (grad/hess -> propose -> bin -> build_tree -> margin update)
is traced ONCE and scanned over pre-split per-round PRNG keys, with the
margin buffer donated into the jit so XLA updates it in place.  Trees
accumulate as a static-shaped struct-of-arrays :class:`tree.Forest`
(the scan's stacked per-round output), so trace+compile cost is O(1) in
``n_trees`` and no host round-trip happens between rounds.  The
jit-able proposal strategies (random / weighted_quantile /
uniform_range) re-propose natively inside the scan; the host-side
strategies (gk_quantile / exact) are x-only — identical candidates
every round — and are proposed once outside it.

:func:`fit_reference` keeps the original per-round Python loop as the
semantic oracle; tests assert the scanned trainer reproduces it
tree-for-tree on a fixed seed.
"""

from __future__ import annotations

import dataclasses
import functools
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from . import binning, predict as predict_lib, proposal, tree as tree_lib
from ..kernels.ops import HistSpec, TraverseSpec
from ..obs import TrainReport, round_report


@dataclasses.dataclass(frozen=True)
class GBDTConfig:
    n_trees: int = 20
    max_depth: int = 6
    learning_rate: float = 0.3
    l2: float = 1.0
    gamma: float = 0.0
    min_child_weight: float = 1.0
    n_candidates: int = 32              # k; nbins = k + 1
    strategy: proposal.Strategy = "random"
    objective: str = "logistic"         # 'logistic' | 'mse'
    repropose_each_round: bool = True   # paper re-proposes per iteration
    backend: str = "auto"               # kernel backend
    telemetry: bool = False             # per-round TrainReport (repro.obs)
    subtract: bool = False              # histogram-subtraction growth:
    #                                     scatter LEFT children only,
    #                                     right = parent - left (halves
    #                                     scatter updates + psum bytes;
    #                                     trees pinned tree-for-tree vs
    #                                     the subtract=False oracles)

    @property
    def nbins(self) -> int:
        return self.n_candidates + 1

    def hist_spec(self) -> HistSpec:
        """The fit-wide histogram workload this config implies: frontier
        width 2^(max_depth-1) nodes, one batched level per tree depth."""
        return HistSpec(n_nodes=2 ** max(self.max_depth - 1, 0),
                        nbins=self.nbins,
                        n_levels=max(self.max_depth, 1),
                        backend=self.backend,
                        subtract=self.subtract)


@dataclasses.dataclass
class GBDTModel:
    config: GBDTConfig
    forest: tree_lib.Forest             # stacked (n_trees, ...) ensemble
    base_score: float
    candidates: jax.Array               # (rounds_proposed, f, k): n_trees
    #                                     when re-proposing a traceable
    #                                     strategy each round, else 1
    #                                     (fixed grid — host-side
    #                                     strategies are x-only).  Both
    #                                     trainers follow this convention.
    proposal_seconds: float = 0.0       # host-side strategies only; the
    #                                     scanned strategies propose
    #                                     inside the compiled loop
    fit_seconds: float = 0.0
    report: TrainReport | None = None   # per-round telemetry when
    #                                     config.telemetry is on

    @property
    def trees(self) -> list[tree_lib.Tree]:
        """Per-tree views (back-compat with the list-of-trees API)."""
        return tree_lib.forest_trees(self.forest)

    @property
    def bin_edges(self) -> jax.Array | None:
        """The (f, k) training candidate grid when it is shared by every
        tree (host-side strategies, or ``repropose_each_round=False``);
        None when the trainer re-proposed a fresh grid per round — the
        binned fast path needs one grid that reproduces every recorded
        threshold, and per-tree grids have no such thing."""
        if self.candidates.shape[0] == 1:
            return self.candidates[0]
        return None

    def bin_features(self, x: jax.Array) -> jax.Array:
        """Bin raw rows against the training grid for binned predict.

        Returns (n, f) uint8 bin ids in [0, k] (int32 when nbins > 256);
        NaN lands in the last bin.  Feed the result to
        ``predict(..., binned=True)`` — binning once and serving many
        batches skips the per-call float threshold gathers.
        """
        edges = self.bin_edges
        if edges is None:
            raise ValueError(
                "binned predict needs a fixed candidate grid; this model "
                "re-proposed candidates per round (strategy="
                f"{self.config.strategy!r}, repropose_each_round=True). "
                "Train with repropose_each_round=False or a host-side "
                "strategy to serve binned.")
        bins = binning.bin_features(jnp.asarray(x, jnp.float32), edges)
        if self.config.nbins <= 256:
            return bins.astype(jnp.uint8)
        return bins

    def predict(self, x: jax.Array, *, output: str = "label",
                binned: bool = False, backend: str | None = None,
                tree_chunk: int | None = None) -> jax.Array:
        """Evaluate the ensemble (batched level-synchronous engine).

        Args:
          output: 'label' — hard 0/1 for logistic, the predicted value
            for mse (the default, and what metrics consume); 'margin' —
            the raw additive score; 'proba' — sigmoid of the margin
            (logistic only).
          binned: traverse on integer bin ids instead of float
            thresholds (exact vs raw on finite rows, NaN goes last-bin
            instead of right).  ``x`` may be raw floats (binned here
            against :attr:`bin_edges`) or already-binned ids from
            :meth:`bin_features`.
          backend: traversal backend override ('auto'/'pallas'/
            'interpret'/'ref'/'packed'); default auto-selects.
          tree_chunk: trees per traversal chunk (compile-time constant
            of the engine's scan step).

        All output modes route through ONE jitted ensemble-sum
        executable per (shapes, spec) — picking 'proba' after 'label'
        does not recompile or re-traverse differently.
        """
        x = jnp.asarray(x)
        if binned and not jnp.issubdtype(x.dtype, jnp.integer):
            x = self.bin_features(x)
        elif binned:
            if self.bin_edges is None:
                raise ValueError(
                    "binned predict needs a fixed candidate grid "
                    "(see GBDTModel.bin_features)")
        else:
            x = x.astype(jnp.float32)
        spec = TraverseSpec(
            tree_chunk=tree_chunk or predict_lib.DEFAULT_TREE_CHUNK,
            binned=binned,
            backend=backend or self.config.backend).resolved()
        m = predict_lib.margin(
            self.forest, x, self.base_score, self.config.learning_rate,
            max_depth=self.config.max_depth, spec=spec)
        if output == "margin":
            return m
        if self.config.objective != "logistic":
            if output == "proba":
                raise ValueError(
                    f"output='proba' needs a logistic objective, got "
                    f"{self.config.objective!r}")
            return m                       # 'label' for regression = value
        p = jax.nn.sigmoid(m)
        if output == "proba":
            return p
        if output == "label":
            return (p > 0.5).astype(jnp.float32)
        raise ValueError(f"unknown output {output!r}")

    def predict_margin(self, x: jax.Array) -> jax.Array:
        """Deprecated: use ``predict(x, output='margin')``."""
        warnings.warn(
            "GBDTModel.predict_margin is deprecated; use "
            "predict(x, output='margin')", DeprecationWarning, stacklevel=2)
        return self.predict(x, output="margin")


def grad_hess(margin: jax.Array, y: jax.Array, objective: str):
    """First/second order stats of the loss wrt the margin."""
    if objective == "logistic":
        p = jax.nn.sigmoid(margin)
        return (p - y).astype(jnp.float32), (p * (1 - p)).astype(jnp.float32)
    if objective == "mse":
        return (margin - y).astype(jnp.float32), jnp.ones_like(margin)
    raise ValueError(f"unknown objective {objective!r}")


def _base_score(y: jax.Array, objective: str) -> float:
    if objective == "logistic":
        p = float(jnp.clip(jnp.mean(y), 1e-6, 1 - 1e-6))
        return float(np.log(p / (1 - p)))
    return float(jnp.mean(y))


def round_keys(key: jax.Array, n_trees: int, offset: int = 0) -> jax.Array:
    """Pre-split per-round keys, identical to fold_in(key, offset + r)."""
    return jax.vmap(lambda r: jax.random.fold_in(key, r))(
        offset + jnp.arange(n_trees))


# ---------------------------------------------------------------------------
# Round-step trace accounting.
#
# The Python body of the scanned round step runs exactly once per trace
# of the surrounding jit, so a module-level counter bumped there IS the
# lowering count of the hot loop.  tests/test_retrace.py asserts it does
# not grow with n_trees.
# ---------------------------------------------------------------------------

_round_traces = 0


def _bump_round_traces() -> None:
    global _round_traces
    _round_traces += 1


def round_trace_count() -> int:
    """How many times a boosting round step has been traced (all trainers)."""
    return _round_traces


@functools.partial(jax.jit,
                   static_argnames=("cfg", "spec"),
                   donate_argnums=(3,))
def _fit_scanned(x, y, keys, margin0, fixed_c, *, cfg: GBDTConfig,
                 spec: HistSpec):
    """Single-compile boosting: lax.scan of one round step over rounds.

    margin0 is donated — the round runner's carry buffer is updated in
    place rather than double-buffered at the jit boundary.  ``spec`` is
    the fit-wide :class:`HistSpec` (already resolved), the one static
    handle the tree builder needs instead of loose kernel kwargs.

    Returns (forest, candidates, margin, report); candidates has a
    leading axis of n_trees when re-proposing inside the scan, else 1.
    ``report`` is a stacked :class:`repro.obs.TrainReport` when
    ``cfg.telemetry`` is on, else None — the per-round rows ride the
    scan as extra outputs, so the telemetry-off graph (and the one
    round-step trace) is unchanged.
    """
    def grow(margin, bins, cands):
        g, h = grad_hess(margin, y, cfg.objective)
        built = tree_lib.build_tree(
            bins, jnp.stack([g, h], 1), cands,
            max_depth=cfg.max_depth, l2=cfg.l2,
            gamma=cfg.gamma, min_child_weight=cfg.min_child_weight,
            spec=spec, return_leaf_nodes=True,
            return_stats=cfg.telemetry)
        t, node = built[0], built[1]
        # growth already routed every row to its leaf — gather the leaf
        # values directly instead of re-descending with predict_binned
        margin = margin + cfg.learning_rate * t.leaf_value[node]
        rep = None
        if cfg.telemetry:
            rep = round_report(margin=margin, y=y, g=g, h=h,
                               objective=cfg.objective, stats=built[2])
        return margin, t, rep

    in_scan = cfg.repropose_each_round and fixed_c is None
    if in_scan:
        def round_step(margin, key_r):
            _bump_round_traces()
            _, h = grad_hess(margin, y, cfg.objective)
            c = proposal.propose(cfg.strategy, x, cfg.n_candidates,
                                 key=key_r, hess=h)
            bins = binning.bin_features(x, c)
            margin, t, rep = grow(margin, bins, c)
            return margin, (t, c, rep)

        margin, (trees, cands, report) = jax.lax.scan(
            round_step, margin0, keys)
        return tree_lib.Forest(*trees), cands, margin, report

    # fixed candidate grid: host-side strategies (candidates passed in)
    # or repropose_each_round=False (proposed once from round-0 stats)
    if fixed_c is None:
        _, h0 = grad_hess(margin0, y, cfg.objective)
        fixed_c = proposal.propose(cfg.strategy, x, cfg.n_candidates,
                                   key=keys[0], hess=h0)
    bins = binning.bin_features(x, fixed_c)

    def round_step(margin, _key_r):
        _bump_round_traces()
        margin, t, rep = grow(margin, bins, fixed_c)
        return margin, (t, rep)

    margin, (trees, report) = jax.lax.scan(round_step, margin0, keys)
    return tree_lib.Forest(*trees), fixed_c[None], margin, report


def fit(x: jax.Array, y: jax.Array, cfg: GBDTConfig,
        key: jax.Array | None = None) -> GBDTModel:
    """Train a GBDT model on a single host (single-compile scan trainer).

    Args:
      x: (n, f) float32 features.
      y: (n,) labels ({0,1} for logistic, real for mse).

    Reproduces :func:`fit_reference` tree-for-tree on the same key.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    t_fit0 = time.perf_counter()

    base = _base_score(y, cfg.objective)
    margin0 = jnp.full((x.shape[0],), base, jnp.float32)
    keys = round_keys(key, cfg.n_trees)
    spec = cfg.hist_spec().resolved()   # pin 'auto' outside the trace

    fixed_c = None
    proposal_s = 0.0
    if cfg.strategy not in proposal.TRACEABLE:
        # host-side strategies are x-only: one proposal serves all rounds
        t0 = time.perf_counter()
        fixed_c = jax.block_until_ready(jnp.asarray(proposal.propose(
            cfg.strategy, x, cfg.n_candidates,
            key=jax.random.fold_in(key, 0))))
        proposal_s = time.perf_counter() - t0

    forest, cands, margin, report = _fit_scanned(
        x, y, keys, margin0, fixed_c, cfg=cfg, spec=spec)
    jax.block_until_ready(margin)
    return GBDTModel(cfg, forest, base, cands,
                     proposal_seconds=proposal_s,
                     fit_seconds=time.perf_counter() - t_fit0,
                     report=report)


def fit_reference(x: jax.Array, y: jax.Array, cfg: GBDTConfig,
                  key: jax.Array | None = None) -> GBDTModel:
    """The original per-round Python loop (one dispatch + host sync per
    round, O(n_trees) trace/compile).  Kept as the semantic oracle for
    the scanned trainer and as the bench baseline — not the fast path.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    t_fit0 = time.perf_counter()

    base = _base_score(y, cfg.objective)
    margin = jnp.full((x.shape[0],), base, jnp.float32)
    spec = cfg.hist_spec()

    trees: list[tree_lib.Tree] = []
    cands: list[jax.Array] = []
    proposal_s = 0.0
    bins = None
    # host-side strategies are x-only (identical candidates every round),
    # so propose once: model.candidates is (1, f, k), matching fit()
    repropose = (cfg.repropose_each_round
                 and cfg.strategy in proposal.TRACEABLE)

    for r in range(cfg.n_trees):
        g, h = grad_hess(margin, y, cfg.objective)
        if repropose or r == 0:
            t0 = time.perf_counter()
            c = proposal.propose(cfg.strategy, x, cfg.n_candidates,
                                 key=jax.random.fold_in(key, r), hess=h)
            c = jax.block_until_ready(c)
            proposal_s += time.perf_counter() - t0
            bins = binning.bin_features(x, c)
            cands.append(c)
        t = tree_lib.build_tree(
            bins, jnp.stack([g, h], 1), cands[-1],
            max_depth=cfg.max_depth, l2=cfg.l2,
            gamma=cfg.gamma, min_child_weight=cfg.min_child_weight,
            spec=spec)
        trees.append(t)
        margin = margin + cfg.learning_rate * tree_lib.predict_binned(
            t, bins, max_depth=cfg.max_depth)

    margin = jax.block_until_ready(margin)
    return GBDTModel(cfg, tree_lib.forest_from_trees(trees), base,
                     jnp.stack(cands),
                     proposal_seconds=proposal_s,
                     fit_seconds=time.perf_counter() - t_fit0)


def accuracy(model: GBDTModel, x, y) -> float:
    if model.config.objective != "logistic":
        raise ValueError("accuracy is for classification")
    lbl = model.predict(x, output="label")
    return float(jnp.mean((lbl > 0.5) == (jnp.asarray(y) > 0.5)))


def mape(model: GBDTModel, x, y) -> float:
    p = model.predict(x, output="label")   # regression 'label' = value
    y = jnp.asarray(y, jnp.float32)
    return float(jnp.mean(jnp.abs((p - y) / jnp.where(y == 0, 1.0, y)))) * 100
