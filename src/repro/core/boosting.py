"""Gradient-boosted decision trees over the binned tree builder.

Single-host reference trainer (the distributed shard_map trainer lives in
distributed.py and reuses the same tree builder).  Mirrors the paper's
experimental setup: proposal strategy is pluggable per-round
('random' = the paper; 'gk_quantile' / 'weighted_quantile' /
'uniform_range' = the data-faithful baselines; 'exact' = greedy).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import binning, proposal, tree as tree_lib


@dataclasses.dataclass(frozen=True)
class GBDTConfig:
    n_trees: int = 20
    max_depth: int = 6
    learning_rate: float = 0.3
    l2: float = 1.0
    gamma: float = 0.0
    min_child_weight: float = 1.0
    n_candidates: int = 32              # k; nbins = k + 1
    strategy: proposal.Strategy = "random"
    objective: str = "logistic"         # 'logistic' | 'mse'
    repropose_each_round: bool = True   # paper re-proposes per iteration
    backend: str = "auto"               # kernel backend

    @property
    def nbins(self) -> int:
        return self.n_candidates + 1


@dataclasses.dataclass
class GBDTModel:
    config: GBDTConfig
    trees: list[tree_lib.Tree]
    base_score: float
    candidates: list[jax.Array]         # per round (f, k)
    proposal_seconds: float = 0.0       # time spent proposing (Table 2 T col)
    fit_seconds: float = 0.0

    def predict_margin(self, x: jax.Array) -> jax.Array:
        out = jnp.full((x.shape[0],), self.base_score, jnp.float32)
        for t in self.trees:
            out = out + self.config.learning_rate * tree_lib.predict_raw(
                t, x, max_depth=self.config.max_depth)
        return out

    def predict(self, x: jax.Array) -> jax.Array:
        m = self.predict_margin(x)
        if self.config.objective == "logistic":
            return jax.nn.sigmoid(m)
        return m


def grad_hess(margin: jax.Array, y: jax.Array, objective: str):
    """First/second order stats of the loss wrt the margin."""
    if objective == "logistic":
        p = jax.nn.sigmoid(margin)
        return (p - y).astype(jnp.float32), (p * (1 - p)).astype(jnp.float32)
    if objective == "mse":
        return (margin - y).astype(jnp.float32), jnp.ones_like(margin)
    raise ValueError(f"unknown objective {objective!r}")


def _base_score(y: jax.Array, objective: str) -> float:
    if objective == "logistic":
        p = float(jnp.clip(jnp.mean(y), 1e-6, 1 - 1e-6))
        return float(np.log(p / (1 - p)))
    return float(jnp.mean(y))


def fit(x: jax.Array, y: jax.Array, cfg: GBDTConfig,
        key: jax.Array | None = None) -> GBDTModel:
    """Train a GBDT model on a single host.

    Args:
      x: (n, f) float32 features.
      y: (n,) labels ({0,1} for logistic, real for mse).
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    t_fit0 = time.perf_counter()

    base = _base_score(y, cfg.objective)
    margin = jnp.full((x.shape[0],), base, jnp.float32)

    trees: list[tree_lib.Tree] = []
    cands: list[jax.Array] = []
    proposal_s = 0.0
    bins = None

    for r in range(cfg.n_trees):
        g, h = grad_hess(margin, y, cfg.objective)
        if cfg.repropose_each_round or r == 0:
            t0 = time.perf_counter()
            c = proposal.propose(cfg.strategy, x, cfg.n_candidates,
                                 key=jax.random.fold_in(key, r), hess=h)
            c = jax.block_until_ready(c)
            proposal_s += time.perf_counter() - t0
            bins = binning.bin_features(x, c)
            cands.append(c)
        t = tree_lib.build_tree(
            bins, jnp.stack([g, h], 1), cands[-1],
            max_depth=cfg.max_depth, nbins=cfg.nbins, l2=cfg.l2,
            gamma=cfg.gamma, min_child_weight=cfg.min_child_weight,
            backend=cfg.backend)
        trees.append(t)
        margin = margin + cfg.learning_rate * tree_lib.predict_binned(
            t, bins, max_depth=cfg.max_depth)

    margin = jax.block_until_ready(margin)
    return GBDTModel(cfg, trees, base, cands,
                     proposal_seconds=proposal_s,
                     fit_seconds=time.perf_counter() - t_fit0)


def accuracy(model: GBDTModel, x, y) -> float:
    p = model.predict(jnp.asarray(x, jnp.float32))
    if model.config.objective == "logistic":
        return float(jnp.mean((p > 0.5) == (jnp.asarray(y) > 0.5)))
    raise ValueError("accuracy is for classification")


def mape(model: GBDTModel, x, y) -> float:
    p = model.predict(jnp.asarray(x, jnp.float32))
    y = jnp.asarray(y, jnp.float32)
    return float(jnp.mean(jnp.abs((p - y) / jnp.where(y == 0, 1.0, y)))) * 100
