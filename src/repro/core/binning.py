"""Bucketising features against a shared candidate grid.

Convention (must stay consistent with tree.py / split.py):

  bin_id(x, c) = #{ c_i < x }  = searchsorted(c, x, side='left')

  A split at candidate index s sends a row LEFT iff bin_id <= s,
  equivalently  x <= c_s  on raw values.  nbins = k + 1.

Binning happens once per proposal (per boosting round for re-proposed
candidates); trees then operate entirely on uint8/int32 bin ids — the
paper's 'data read' stage.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


# Above this many candidates the O(n*f*k) dense comparison loses to the
# O(n*f*log k) search; k=64 keeps the dense path for every config the
# paper sweeps (k in {8..64}).
_DENSE_K_MAX = 64


@jax.jit
def bin_features(x: jax.Array, candidates: jax.Array) -> jax.Array:
    """Map raw features to bin ids.

    For k <= 64 this counts ``sum_i [c_i < x]`` with one dense broadcast
    comparison — integer-identical to ``searchsorted(side='left')`` on
    sorted candidates (both count the candidates strictly below x,
    including ties/duplicates) and ~25x faster through XLA:CPU, which
    vectorises the comparison but not the per-element binary search.
    NaN rows go to the LAST bin (k) on both paths: searchsorted places
    NaN at k natively, and the dense count — whose comparisons are all
    false for NaN — routes it there explicitly, so a NaN never splits
    left of any finite threshold regardless of k.

    Args:
      x: (n, f) raw features.
      candidates: (f, k) sorted candidate values.

    Returns:
      (n, f) int32 bin ids in [0, k].
    """
    with jax.named_scope("repro.bin_features"):
        k = candidates.shape[1]
        if k <= _DENSE_K_MAX:
            dense = (x[:, :, None] > candidates[None, :, :]).astype(
                jnp.int32).sum(axis=2)
            return jnp.where(jnp.isnan(x), k, dense)

        def per_feature(col, cand):
            return jnp.searchsorted(cand, col, side="left").astype(jnp.int32)

        return jax.vmap(per_feature, in_axes=(1, 0), out_axes=1)(x, candidates)


@partial(jax.jit, static_argnames=("nbins",))
def bin_counts(bins: jax.Array, nbins: int) -> jax.Array:
    """Histogram of rows per (feature, bin) — diagnostics/load stats."""
    n, f = bins.shape
    one_hot = jax.nn.one_hot(bins, nbins, dtype=jnp.int32)  # (n, f, nbins)
    return one_hot.sum(axis=0)
