"""Bucketising features against a shared candidate grid.

Convention (must stay consistent with tree.py / split.py):

  bin_id(x, c) = #{ c_i < x }  = searchsorted(c, x, side='left')

  A split at candidate index s sends a row LEFT iff bin_id <= s,
  equivalently  x <= c_s  on raw values.  nbins = k + 1.

Binning happens once per proposal (per boosting round for re-proposed
candidates); trees then operate entirely on uint8/int32 bin ids — the
paper's 'data read' stage.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@jax.jit
def bin_features(x: jax.Array, candidates: jax.Array) -> jax.Array:
    """Map raw features to bin ids.

    Args:
      x: (n, f) raw features.
      candidates: (f, k) sorted candidate values.

    Returns:
      (n, f) int32 bin ids in [0, k].
    """
    def per_feature(col, cand):
        return jnp.searchsorted(cand, col, side="left").astype(jnp.int32)

    return jax.vmap(per_feature, in_axes=(1, 0), out_axes=1)(x, candidates)


@partial(jax.jit, static_argnames=("nbins",))
def bin_counts(bins: jax.Array, nbins: int) -> jax.Array:
    """Histogram of rows per (feature, bin) — diagnostics/load stats."""
    n, f = bins.shape
    one_hot = jax.nn.one_hot(bins, nbins, dtype=jnp.int32)  # (n, f, nbins)
    return one_hot.sum(axis=0)
