"""Pallas TPU kernel: batched level-synchronous forest traversal.

Tree-ensemble inference is memory-gather bound: every depth level of
every tree wants ``x[row, feature[node]]`` for a different (row, node)
pair.  GPUs take the gathers; TPUs have neither scalar gathers in the
vector unit nor atomics, so — like the histogram kernel's
histogram-as-matmul trick — the TPU-native formulation replaces every
gather with a **masked-select reduction** over a static axis:

  field[r, t]  =  sum_j  where(node[r, t] == j, field_level[t, j], 0)

The select axis is tiny (the level's frontier width ``2^d``, then the
feature count ``f``), the compares and sums run on the VPU over fully
static shapes, and exactly one mask lane is hot per (row, tree) — so
the select is also *value-exact* (one non-zero term; adding zeros never
re-associates anything), which keeps the kernel bit-identical to the
jnp reference path.

One launch descends a whole tree chunk: grid over row tiles only, the
chunk's SoA arrays (feature / cmp / leaf) stay resident in VMEM while
row tiles stream through, and the depth loop is unrolled inside the
kernel (static ``max_depth``).  Output is the per-tree leaf-value
matrix ``(rows, trees)``; the caller owns the ensemble summation order.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_ROW_TILE = 256


def _traverse_kernel(vals_ref, feat_ref, cmp_ref, leaf_ref, out_ref, *,
                     max_depth: int):
    vals = vals_ref[...]                    # (rt, f) float32 or int32
    feat = feat_ref[...]                    # (C, 2^d - 1) int32
    cmp = cmp_ref[...]                      # (C, 2^d - 1) f32 or int32
    leaf = leaf_ref[...]                    # (C, 2^d) float32
    rt, f = vals.shape
    C = feat.shape[0]

    node = jnp.zeros((rt, C), jnp.int32)    # level-local node ids
    for depth in range(max_depth):
        base = 2 ** depth - 1
        width = 2 ** depth                  # node ids live in [0, width)
        lvl_feat = feat[:, base:base + width]       # static level slice
        lvl_cmp = cmp[:, base:base + width]
        # masked-select the (feature, cmp) node record: one hot lane per
        # (row, tree), so the sum is exact (never re-associates)
        sel = node[:, :, None] == jax.lax.broadcasted_iota(
            jnp.int32, (rt, C, width), 2)
        fidx = jnp.sum(jnp.where(sel, lvl_feat[None], 0), axis=2)
        cval = jnp.sum(jnp.where(sel, lvl_cmp[None], 0), axis=2)
        # masked-select the row's feature value (clip -1 passthrough to
        # feature 0, same as the jnp descent — keeps NaN routing aligned)
        fsel = fidx.clip(0)[:, :, None] == jax.lax.broadcasted_iota(
            jnp.int32, (rt, C, f), 2)
        xv = jnp.sum(jnp.where(fsel, vals[:, None, :], 0), axis=2)
        node = node * 2 + jnp.where(xv <= cval, 0, 1)

    lsel = node[:, :, None] == jax.lax.broadcasted_iota(
        jnp.int32, (rt, C, leaf.shape[1]), 2)
    out_ref[...] = jnp.sum(jnp.where(lsel, leaf[None], 0.0), axis=2)


@functools.partial(jax.jit, static_argnames=("max_depth", "row_tile",
                                             "interpret"))
def traverse_chunk_pallas(values: jax.Array, feature: jax.Array,
                          cmp: jax.Array, leaf: jax.Array, *,
                          max_depth: int,
                          row_tile: int = DEFAULT_ROW_TILE,
                          interpret: bool = False) -> jax.Array:
    """Per-tree leaf values of a stacked tree chunk in one launch.

    Args:
      values: (n, f) raw float32 features or int32 bin ids.
      feature: (C, 2^max_depth - 1) int32; -1 = passthrough.
      cmp: (C, 2^max_depth - 1) float32 thresholds or int32 split bins
        (must match the dtype/mode of ``values``).
      leaf: (C, 2^max_depth) float32 leaf values.
      row_tile: rows per grid step (VMEM knob).

    Returns:
      (n, C) float32 — bit-identical to
      :func:`repro.kernels.ref.traverse_chunk_ref` (the masked-select
      sums have exactly one hot lane, so nothing re-associates).
    """
    n, f = values.shape
    C, n_inner = feature.shape
    n_leaves = leaf.shape[1]
    if max_depth == 0 or n_inner == 0:
        # depth-0 forest: every row lands in the single leaf
        return jnp.broadcast_to(leaf[:, 0][None, :], (n, C))

    n_pad = -n % row_tile
    if n_pad:
        values = jnp.pad(values, ((0, n_pad), (0, 0)))
    nt = (n + n_pad) // row_tile

    out = pl.pallas_call(
        functools.partial(_traverse_kernel, max_depth=max_depth),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((row_tile, f), lambda t: (t, 0)),
            pl.BlockSpec((C, n_inner), lambda t: (0, 0)),
            pl.BlockSpec((C, n_inner), lambda t: (0, 0)),
            pl.BlockSpec((C, n_leaves), lambda t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, C), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((n + n_pad, C), jnp.float32),
        interpret=interpret,
    )(values, feature, cmp, leaf)
    return out[:n]
