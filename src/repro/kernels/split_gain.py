"""Pallas TPU kernel: best-split scan over histogram bins.

Given the (node, feature, bin, {g,h}) histogram, compute for every
(node, feature) the split position maximising the XGBoost gain

  gain(s) = 1/2 [ GL(s)^2/(HL(s)+l2) + GR(s)^2/(HR(s)+l2) - G^2/(H+l2) ] - gamma

subject to min_child_weight on both sides.  One grid step per node; the
whole (features, nbins) panel for a node lives in VMEM (f*nbins*2 floats —
a few hundred KB for realistic f<=512, nbins<=256).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _split_gain_kernel(hist_ref, gain_ref, idx_ref, *,
                       l2: float, gamma: float, min_child_weight: float):
    hist = hist_ref[0]                       # (f, nbins, 2) f32
    g = hist[..., 0]
    h = hist[..., 1]
    gl = jnp.cumsum(g, axis=1)               # (f, nbins) left sums incl bin s
    hl = jnp.cumsum(h, axis=1)
    gt = gl[:, -1:]
    ht = hl[:, -1:]
    gr = gt - gl
    hr = ht - hl

    def score(gg, hh):
        return (gg * gg) / (hh + l2)

    gain = 0.5 * (score(gl, hl) + score(gr, hr) - score(gt, ht)) - gamma
    ok = (hl >= min_child_weight) & (hr >= min_child_weight)
    # splitting at the last bin puts everything left — never useful
    nbins = gain.shape[1]
    pos = jax.lax.broadcasted_iota(jnp.int32, gain.shape, 1)
    ok &= pos < (nbins - 1)
    gain = jnp.where(ok, gain, -jnp.inf)

    gain_ref[0] = jnp.max(gain, axis=1)
    idx_ref[0] = jnp.argmax(gain, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=(
    "l2", "gamma", "min_child_weight", "interpret"))
def split_gain_pallas(hist: jax.Array, *, l2: float = 1.0, gamma: float = 0.0,
                      min_child_weight: float = 1e-6,
                      interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Best gain and split-bin per (node, feature).

    Args:
      hist: (n_nodes, f, nbins, 2) float32 histogram.

    Returns:
      gains: (n_nodes, f) float32 (-inf where no legal split).
      idx:   (n_nodes, f) int32 best bin index s (split: bin <= s goes left).
    """
    n_nodes, f, nbins, _ = hist.shape
    kern = functools.partial(_split_gain_kernel, l2=float(l2),
                             gamma=float(gamma),
                             min_child_weight=float(min_child_weight))
    gains, idx = pl.pallas_call(
        kern,
        grid=(n_nodes,),
        in_specs=[pl.BlockSpec((1, f, nbins, 2), lambda i: (i, 0, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, f), lambda i: (i, 0)),
            pl.BlockSpec((1, f), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_nodes, f), jnp.float32),
            jax.ShapeDtypeStruct((n_nodes, f), jnp.int32),
        ],
        interpret=interpret,
    )(hist)
    return gains, idx
