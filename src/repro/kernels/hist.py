"""Pallas TPU kernel: gradient/hessian histogram accumulation.

This is the hot loop of distributed GBDT (the paper's Table 2 timing is
dominated by it once proposal is cheap).  GPU implementations use atomic
scatter-adds into shared-memory histograms; TPUs have no atomics, so the
TPU-native formulation is **histogram-as-matmul**:

  for a tile of rows, build the one-hot matrix  O[r, (node,bin)]  and
  contract it with the (rows, 2) grad/hess panel on the MXU:

      hist[f, node*nbins+bin, :] += O.T @ [g h]

The one-hot never leaves VMEM; the contraction dimension (rows tile) is a
multiple of 128 so the MXU is fully utilised.

The level-batched entry point :func:`hist_levels_pallas` accumulates the
histograms of L node-id assignments ("levels") of the same rows in one
launch: the grid's middle axis enumerates (level, node_chunk) pairs, so
every frontier (level, node) block lives in VMEM while its row tiles
stream through — one kernel for the whole frontier instead of one launch
per level.  Grid is (features, level*node_chunks, row_tiles) with the
row_tiles axis innermost and accumulating into the same output block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_ROW_TILE = 512


def _hist_levels_kernel(bins_ref, node_ref, gh_ref, out_ref, *,
                        nbins: int, node_chunk: int, n_chunks: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bins = bins_ref[:, 0]                       # (rt,) int32
    node = node_ref[:, 0]                       # (rt,) int32 (-1 = padding)
    gh = gh_ref[...].astype(jnp.float32)        # (rt, 2)

    # middle grid axis c enumerates (level, chunk): level = c // n_chunks
    # is encoded in the node BlockSpec; only the chunk offset matters here.
    base = (pl.program_id(1) % n_chunks) * node_chunk
    local = node - base
    valid = (local >= 0) & (local < node_chunk)
    idx = jnp.where(valid, local * nbins + bins, 0)

    width = node_chunk * nbins
    cols = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], width), 1)
    onehot = ((idx[:, None] == cols) & valid[:, None]).astype(jnp.float32)

    out_ref[0] += jnp.dot(onehot.T, gh, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=(
    "n_nodes", "nbins", "row_tile", "node_chunk", "interpret"))
def hist_levels_pallas(bins: jax.Array, node_per_level: jax.Array,
                       gh: jax.Array, *, n_nodes: int, nbins: int,
                       row_tile: int = DEFAULT_ROW_TILE,
                       node_chunk: int = 0,
                       interpret: bool = False) -> jax.Array:
    """Per-(level, node, feature, bin) grad/hess sums in one launch.

    Args:
      bins: (n, f) int32 bin ids in [0, nbins).
      node_per_level: (L, n) int32 node assignment per level in
        [0, n_nodes); negative = row masked out at that level.
      gh: (n, 2) float grad/hess panel.
      n_nodes: frontier nodes per level.
      nbins: bins per feature.
      node_chunk: nodes per output block (VMEM knob); 0 = auto.

    Returns:
      (L, n_nodes, f, nbins, 2) float32 histogram.
    """
    L, _ = node_per_level.shape
    n, f = bins.shape
    if node_chunk <= 0:
        # keep the one-hot tile under ~8 MB of VMEM: rt * chunk*nbins * 4B
        node_chunk = max(1, min(n_nodes, (8 * 2 ** 20) // (row_tile * nbins * 4)))
    n_chunks = -(-n_nodes // node_chunk)
    nodes_padded = n_chunks * node_chunk

    # pad rows to a tile multiple; padding rows get node=-1 (masked out)
    node_t = node_per_level.T                   # (n, L): row-tiled blocks
    n_pad = -n % row_tile
    if n_pad:
        bins = jnp.pad(bins, ((0, n_pad), (0, 0)))
        node_t = jnp.pad(node_t, ((0, n_pad), (0, 0)), constant_values=-1)
        gh = jnp.pad(gh, ((0, n_pad), (0, 0)))
    nt = (n + n_pad) // row_tile

    out = pl.pallas_call(
        functools.partial(_hist_levels_kernel, nbins=nbins,
                          node_chunk=node_chunk, n_chunks=n_chunks),
        grid=(f, L * n_chunks, nt),
        in_specs=[
            pl.BlockSpec((row_tile, 1), lambda fi, c, t: (t, fi)),
            pl.BlockSpec((row_tile, 1), lambda fi, c, t: (t, c // n_chunks)),
            pl.BlockSpec((row_tile, 2), lambda fi, c, t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((1, node_chunk * nbins, 2),
                               lambda fi, c, t: (fi, c, 0)),
        out_shape=jax.ShapeDtypeStruct((f, L * nodes_padded * nbins, 2),
                                       jnp.float32),
        interpret=interpret,
    )(bins, node_t, gh)

    out = out.reshape(f, L, nodes_padded, nbins, 2)[:, :, :n_nodes]
    return jnp.transpose(out, (1, 2, 0, 3, 4))


@functools.partial(jax.jit, static_argnames=(
    "n_nodes", "nbins", "row_tile", "node_chunk", "interpret"))
def hist_levels_left_pallas(bins: jax.Array, node_per_level: jax.Array,
                            gh: jax.Array, *, n_nodes: int, nbins: int,
                            row_tile: int = DEFAULT_ROW_TILE,
                            node_chunk: int = 0,
                            interpret: bool = False) -> jax.Array:
    """Subtraction child mode: left-routed rows only, parent-keyed panel.

    ``node_per_level`` holds CHILD frontier ids in ``[0, 2 * n_nodes)``;
    rows routed RIGHT (odd id) are masked to -1 and contribute a zero
    one-hot row, so the launch accumulates only the left children into
    ``n_nodes`` PARENT buckets.  The MXU contraction cost per tile is
    unchanged (the one-hot is half as wide but still dense), but the
    output panel — and therefore the HBM writes and any downstream
    ``lax.psum`` — is half the full-frontier panel.

    Returns:
      (n_levels, n_nodes, f, nbins, 2) float32.
    """
    left = (node_per_level >= 0) & (node_per_level % 2 == 0)
    parent = jnp.where(left, node_per_level // 2, -1)
    return hist_levels_pallas(bins, parent, gh, n_nodes=n_nodes,
                              nbins=nbins, row_tile=row_tile,
                              node_chunk=node_chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "n_nodes", "nbins", "row_tile", "node_chunk", "interpret"))
def hist_pallas(bins: jax.Array, node: jax.Array, gh: jax.Array, *,
                n_nodes: int, nbins: int,
                row_tile: int = DEFAULT_ROW_TILE,
                node_chunk: int = 0,
                interpret: bool = False) -> jax.Array:
    """Per-(node, feature, bin) grad/hess sums — single-level view of
    :func:`hist_levels_pallas`.

    Returns:
      (n_nodes, f, nbins, 2) float32 histogram.
    """
    return hist_levels_pallas(bins, node[None], gh, n_nodes=n_nodes,
                              nbins=nbins, row_tile=row_tile,
                              node_chunk=node_chunk, interpret=interpret)[0]
