"""Public jit'd wrappers for the Pallas kernels.

Each op picks between the Pallas kernel (TPU, or interpret=True for CPU
validation) and the pure-jnp oracle in ref.py.  Call sites in the library
go through these wrappers only — never through the kernels directly — so
backend selection is a single switch.

The histogram hot path is fronted by a small kernel API: a
:class:`HistSpec` (static shape/backend/dtype policy, hashable so it can
ride through ``jax.jit`` static args) plus :func:`hist_levels`, the
level-batched entry point.  Library code builds one spec per fit and
passes it down instead of hand-threading ``n_nodes``/``nbins``/
``backend`` kwargs through every layer.
"""

from __future__ import annotations

import dataclasses
import warnings

import jax

from . import ref
from .hist import hist_levels_left_pallas, hist_levels_pallas, hist_pallas
from .split_gain import split_gain_pallas
from .traverse import traverse_chunk_pallas
from .flash_attention import flash_attention_pallas


_BACKENDS = ("auto", "pallas", "interpret", "ref", "packed")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve(backend: str) -> str:
    """Resolve 'auto' to a concrete backend name.

    The scanned trainers call this once per fit, outside traced code, so
    the choice is a static constant of the compiled program (and
    ``jax.default_backend()`` is never consulted mid-trace).  On CPU
    'auto' picks 'packed' — the complex64-scatter histogram, bit-exact
    vs the 'ref' oracle but ~1.6x faster through XLA:CPU.
    """
    if backend == "auto":
        return "pallas" if _on_tpu() else "packed"
    if backend not in _BACKENDS:
        raise ValueError(f"unknown backend {backend!r}")
    return backend


@dataclasses.dataclass(frozen=True)
class HistSpec:
    """Static description of a histogram workload.

    Frozen + hashable so a spec is a valid ``jax.jit`` static argument:
    one spec per fit rides through the trainers and the tree builder
    instead of loose ``n_nodes``/``nbins``/``backend`` kwargs.

    Attributes:
      n_nodes: frontier nodes per level (the widest level this spec
        serves; shallower levels just leave high node ids empty).
      nbins: bins per feature (``n_candidates + 1``).
      n_levels: node-id assignments batched per :func:`hist_levels`
        call.  A tree builder growing ``max_depth`` levels uses
        ``n_levels = max_depth`` as its fit-wide spec and derives the
        per-call view with :meth:`with_levels`.
      backend: 'auto' | 'pallas' | 'interpret' | 'ref' | 'packed'.
      acc_dtype: accumulator dtype policy.  Only 'float32' is
        supported — it is the bit-exactness contract with ``hist_ref``
        — but it is part of the spec so a future bf16/f64 policy is an
        API no-op.
      subtract: histogram-subtraction policy.  ``False`` (the oracle
        path) scatters every row into the full frontier panel.  ``True``
        switches :func:`hist_levels` to CHILD MODE: ``node_per_level``
        carries child frontier ids in ``[0, 2 * n_nodes)``, only rows
        routed LEFT (even id) are scattered, keyed by the parent id
        ``child >> 1``, and the panel has ``n_nodes`` PARENT buckets —
        the grower reconstructs each right child as ``parent - left``
        from its cached previous-level panel.  Halves the logical
        scatter-update count and the panel entering any distributed
        ``lax.psum``; raw histogram values are no longer bit-exact vs
        direct accumulation (float subtraction re-associates), so the
        exactness contract moves up a level: trees must match the
        ``subtract=False`` oracles tree-for-tree on pinned workloads
        while raw histograms are tolerance-checked.
    """
    n_nodes: int
    nbins: int
    n_levels: int = 1
    backend: str = "auto"
    acc_dtype: str = "float32"
    subtract: bool = False

    def __post_init__(self):
        if self.n_nodes < 1:
            raise ValueError(f"n_nodes must be >= 1, got {self.n_nodes}")
        if self.nbins < 1:
            raise ValueError(f"nbins must be >= 1, got {self.nbins}")
        if self.n_levels < 1:
            raise ValueError(f"n_levels must be >= 1, got {self.n_levels}")
        if self.backend not in _BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.acc_dtype != "float32":
            raise ValueError(
                f"acc_dtype {self.acc_dtype!r} unsupported: 'float32' is "
                "the bit-exactness contract with hist_ref")

    def resolved(self) -> "HistSpec":
        """Spec with 'auto' pinned to a concrete backend (call once per
        fit, outside traced code)."""
        return dataclasses.replace(self, backend=resolve(self.backend))

    def with_levels(self, n_levels: int) -> "HistSpec":
        """Same spec serving a different number of batched levels."""
        return dataclasses.replace(self, n_levels=n_levels)

    def child_view(self) -> "HistSpec":
        """The half-width parent-keyed panel a subtraction grower
        scatters into: ``n_nodes`` halved (full frontier -> parent
        count), subtract mode pinned on."""
        return dataclasses.replace(self, n_nodes=max(self.n_nodes // 2, 1),
                                   subtract=True)


def hist_levels(bins, node_per_level, gh, spec: HistSpec):
    """Level-batched gradient/hessian histogram.

    One call accumulates the histograms of ``spec.n_levels`` node-id
    assignments of the same rows, keyed by (level, node, feature, bin):
    the packed CPU backend issues a single complex64 scatter across all
    levels, the Pallas backend a single launch whose grid covers the
    whole (level, node) frontier.

    Args:
      bins: (n, f) int32 bin ids in [0, spec.nbins).
      node_per_level: (spec.n_levels, n) int32 node ids per level;
        negative = row masked out at that level.  Direct mode
        (``spec.subtract=False``): ids in [0, spec.n_nodes).  Child mode
        (``spec.subtract=True``): CHILD frontier ids in
        [0, 2 * spec.n_nodes) — only even (LEFT-routed) ids contribute,
        keyed by the parent id ``child >> 1``.
      gh: (n, 2) float grad/hess panel.
      spec: static workload description (resolve 'auto' outside traced
        code via ``spec.resolved()`` when tracing matters).

    Returns:
      (spec.n_levels, spec.n_nodes, f, nbins, 2) float32 — bit-exact vs
      a per-level :func:`repro.kernels.ref.hist_ref` loop on the 'ref'
      and 'packed' backends (in child mode, vs
      :func:`repro.kernels.ref.hist_levels_left_ref`).
    """
    if node_per_level.ndim != 2 or node_per_level.shape[0] != spec.n_levels:
        raise ValueError(
            f"node_per_level must be (n_levels={spec.n_levels}, n), got "
            f"shape {node_per_level.shape}")
    backend = resolve(spec.backend)
    # named_scope: the hot-loop kernels show up as one annotated region
    # per op in profiler traces (jax.profiler / perfetto), keyed by
    # backend so packed-vs-pallas time is separable
    if spec.subtract:
        with jax.named_scope(f"repro.hist_levels_left[{backend}]"):
            if backend == "packed":
                return ref.hist_levels_left_packed(bins, node_per_level,
                                                   gh, n_nodes=spec.n_nodes,
                                                   nbins=spec.nbins)
            if backend == "ref":
                return ref.hist_levels_left_ref(bins, node_per_level, gh,
                                                n_nodes=spec.n_nodes,
                                                nbins=spec.nbins)
            return hist_levels_left_pallas(
                bins, node_per_level, gh, n_nodes=spec.n_nodes,
                nbins=spec.nbins, interpret=(backend == "interpret"))
    with jax.named_scope(f"repro.hist_levels[{backend}]"):
        if backend == "packed":
            return ref.hist_levels_packed(bins, node_per_level, gh,
                                          n_nodes=spec.n_nodes,
                                          nbins=spec.nbins)
        if backend == "ref":
            return ref.hist_levels_ref(bins, node_per_level, gh,
                                       n_nodes=spec.n_nodes,
                                       nbins=spec.nbins)
        return hist_levels_pallas(bins, node_per_level, gh,
                                  n_nodes=spec.n_nodes, nbins=spec.nbins,
                                  interpret=(backend == "interpret"))


@dataclasses.dataclass(frozen=True)
class TraverseSpec:
    """Static description of a batched forest-traversal workload.

    The inference-side sibling of :class:`HistSpec`: frozen + hashable,
    so one spec rides through ``jax.jit`` static args instead of loose
    chunk/backend kwargs.  ``repro.core.predict`` builds one per predict
    call and the backends underneath are swapped by this single switch.

    Attributes:
      tree_chunk: trees advanced together per level-synchronous chunk.
        Working memory of the engine is O(rows * tree_chunk); the chunk
        scan keeps the compile count O(1) in ``n_trees`` (forests are
        padded with passthrough zero-leaf trees up to a chunk multiple).
        Default 25 won the 500x6 CPU sweep in
        ``benchmarks/bench_predict.py``.
      binned: traverse on int bin ids (``bin <= split_bin``) instead of
        raw float thresholds (``x <= threshold``).  Exact vs the raw
        path on finite rows when the bin ids come from the training
        candidate grid — thresholds ARE bin boundaries; NaN rows bin to
        the LAST bin (so they follow the binned routing) while raw NaN
        compares False and routes RIGHT.
      backend: 'auto' | 'pallas' | 'interpret' | 'ref' | 'packed'; same
        resolution rule as histograms ('auto' -> pallas on TPU, packed
        elsewhere).
    """
    tree_chunk: int = 25
    binned: bool = False
    backend: str = "auto"

    def __post_init__(self):
        if self.tree_chunk < 1:
            raise ValueError(
                f"tree_chunk must be >= 1, got {self.tree_chunk}")
        if self.backend not in _BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}")

    def resolved(self) -> "TraverseSpec":
        """Spec with 'auto' pinned to a concrete backend (call once per
        predict, outside traced code)."""
        return dataclasses.replace(self, backend=resolve(self.backend))


def traverse_chunk(values, feature, cmp, leaf, spec: TraverseSpec, *,
                   max_depth: int):
    """Level-synchronous descent of one chunk of stacked trees.

    All ``C = feature.shape[0]`` trees advance one depth level per step:
    a single fused gather (or masked-select on the Pallas path) fetches
    every (row, tree) node record, one comparison routes the whole
    (rows, trees) matrix a level down.

    Args:
      values: (n, f) raw float32 features, or int32 bin ids when
        ``spec.binned``.
      feature: (C, 2^max_depth - 1) int32 split features; -1 =
        passthrough.
      cmp: (C, 2^max_depth - 1) float32 thresholds (raw) or int32 split
        bins (binned).
      leaf: (C, 2^max_depth) float32 leaf values.
      spec: static workload description (resolve 'auto' outside traced
        code via ``spec.resolved()`` when tracing matters).

    Returns:
      (n, C) float32 PER-TREE leaf values — summation is left to the
      caller so the engine can accumulate in tree order, keeping the
      ensemble sum bit-identical to the sequential per-tree scan.  All
      backends agree bitwise (`ref` is the vmapped per-tree oracle).
    """
    backend = resolve(spec.backend)
    with jax.named_scope(f"repro.traverse[{backend}]"):
        if backend == "packed":
            return ref.traverse_chunk_packed(values, feature, cmp, leaf,
                                             max_depth=max_depth)
        if backend == "ref":
            return ref.traverse_chunk_ref(values, feature, cmp, leaf,
                                          max_depth=max_depth)
        return traverse_chunk_pallas(values, feature, cmp, leaf,
                                     max_depth=max_depth,
                                     interpret=(backend == "interpret"))


def hist(bins, node, gh, *, n_nodes: int, nbins: int,
         backend: str = "auto"):
    """Deprecated: single-level histogram shim.

    Build a :class:`HistSpec` and call
    ``hist_levels(bins, node[None], gh, spec)[0]`` instead (see README
    "Architecture" for the timeline).
    """
    warnings.warn(
        "ops.hist is deprecated; build a HistSpec and call "
        "hist_levels(bins, node[None], gh, spec)[0]",
        DeprecationWarning, stacklevel=2)
    spec = HistSpec(n_nodes=n_nodes, nbins=nbins, n_levels=1,
                    backend=backend)
    return hist_levels(bins, node[None], gh, spec)[0]


def split_gain(hist_arr, *, l2: float = 1.0, gamma: float = 0.0,
               min_child_weight: float = 1e-6, backend: str = "auto"):
    """Best (gain, bin) per (node, feature) from a histogram."""
    backend = resolve(backend)
    with jax.named_scope(f"repro.split_gain[{backend}]"):
        if backend in ("ref", "packed"):  # 'packed' only specialises hist
            return ref.split_gain_ref(hist_arr, l2=l2, gamma=gamma,
                                      min_child_weight=min_child_weight)
        return split_gain_pallas(hist_arr, l2=l2, gamma=gamma,
                                 min_child_weight=min_child_weight,
                                 interpret=(backend == "interpret"))


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    backend: str = "auto"):
    """Blockwise attention with GQA + optional sliding window."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend == "ref":
        return ref.attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  interpret=(backend == "interpret"))
