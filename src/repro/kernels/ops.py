"""Public jit'd wrappers for the Pallas kernels.

Each op picks between the Pallas kernel (TPU, or interpret=True for CPU
validation) and the pure-jnp oracle in ref.py.  Call sites in the library
go through these wrappers only — never through the kernels directly — so
backend selection is a single switch.
"""

from __future__ import annotations

import jax

from . import ref
from .hist import hist_pallas
from .split_gain import split_gain_pallas
from .flash_attention import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve(backend: str) -> str:
    """Resolve 'auto' to a concrete backend name.

    The scanned trainers call this once per fit, outside traced code, so
    the choice is a static constant of the compiled program (and
    ``jax.default_backend()`` is never consulted mid-trace).  On CPU
    'auto' picks 'packed' — the complex64-scatter histogram, bit-exact
    vs the 'ref' oracle but ~1.6x faster through XLA:CPU.
    """
    if backend == "auto":
        return "pallas" if _on_tpu() else "packed"
    if backend not in ("pallas", "interpret", "ref", "packed"):
        raise ValueError(f"unknown backend {backend!r}")
    return backend


def hist(bins, node, gh, *, n_nodes: int, nbins: int,
         backend: str = "auto"):
    """Gradient/hessian histogram: (n_nodes, f, nbins, 2).

    backend: 'auto' | 'pallas' | 'interpret' | 'ref' | 'packed'
    """
    backend = resolve(backend)
    if backend == "packed":
        return ref.hist_packed(bins, node, gh, n_nodes=n_nodes, nbins=nbins)
    if backend == "ref":
        return ref.hist_ref(bins, node, gh, n_nodes=n_nodes, nbins=nbins)
    return hist_pallas(bins, node, gh, n_nodes=n_nodes, nbins=nbins,
                       interpret=(backend == "interpret"))


def split_gain(hist_arr, *, l2: float = 1.0, gamma: float = 0.0,
               min_child_weight: float = 1e-6, backend: str = "auto"):
    """Best (gain, bin) per (node, feature) from a histogram."""
    backend = resolve(backend)
    if backend in ("ref", "packed"):    # 'packed' only specialises hist
        return ref.split_gain_ref(hist_arr, l2=l2, gamma=gamma,
                                  min_child_weight=min_child_weight)
    return split_gain_pallas(hist_arr, l2=l2, gamma=gamma,
                             min_child_weight=min_child_weight,
                             interpret=(backend == "interpret"))


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    backend: str = "auto"):
    """Blockwise attention with GQA + optional sliding window."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend == "ref":
        return ref.attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  interpret=(backend == "interpret"))
