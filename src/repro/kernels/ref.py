"""Pure-jnp oracles for every kernel in this package.

These are the correctness ground truth for the Pallas kernels
(tests assert allclose against them across shape/dtype sweeps) and the
fallback implementation on backends without Pallas support.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("n_nodes", "nbins"))
def hist_ref(bins: jax.Array, node: jax.Array, gh: jax.Array, *,
             n_nodes: int, nbins: int) -> jax.Array:
    """(n_nodes, f, nbins, 2) grad/hess histogram via scatter-add."""
    n, f = bins.shape
    valid = node >= 0
    node_c = jnp.where(valid, node, 0)
    # flat index: ((node * f) + feat) * nbins + bin
    flat = (node_c[:, None] * f + jnp.arange(f)[None, :]) * nbins + bins
    w = jnp.where(valid, 1.0, 0.0)
    vals = jnp.broadcast_to((gh * w[:, None])[:, None, :],
                            (n, f, 2)).astype(jnp.float32)
    out = jnp.zeros((n_nodes * f * nbins, 2), jnp.float32)
    out = out.at[flat.ravel()].add(vals.reshape(n * f, 2))
    return out.reshape(n_nodes, f, nbins, 2)


@functools.partial(jax.jit, static_argnames=("n_nodes", "nbins"))
def hist_levels_ref(bins: jax.Array, node_per_level: jax.Array,
                    gh: jax.Array, *, n_nodes: int, nbins: int) -> jax.Array:
    """Oracle for the level-batched histogram: a naive per-level loop of
    :func:`hist_ref`, stacked along a leading level axis.

    Args:
      node_per_level: (n_levels, n) int32 node ids per level in
        [0, n_nodes); negative = row masked out at that level.

    Returns:
      (n_levels, n_nodes, f, nbins, 2) float32.
    """
    return jnp.stack([
        hist_ref(bins, node_per_level[lvl], gh, n_nodes=n_nodes, nbins=nbins)
        for lvl in range(node_per_level.shape[0])])


@functools.partial(jax.jit, static_argnames=("n_nodes", "nbins"))
def hist_levels_packed(bins: jax.Array, node_per_level: jax.Array,
                       gh: jax.Array, *, n_nodes: int,
                       nbins: int) -> jax.Array:
    """Level-batched CPU histogram: ONE complex64 scatter keyed by
    (level, node, feature, bin).

    Bit-exact vs :func:`hist_levels_ref`: buckets are disjoint across
    levels and features, the real/imag lanes add independently, and
    within each bucket the updates arrive in the same row order as the
    per-level scatter.  The feature-bin offset ``fb`` and the packed
    grad/hess panel are level-invariant, so batching L levels amortises
    the index arithmetic that a per-level loop would recompute (and lets
    XLA hoist both out of a level-step ``lax.scan``).
    """
    L, n = node_per_level.shape
    f = bins.shape[1]
    valid = node_per_level >= 0                            # (L, n)
    node_c = jnp.where(valid, node_per_level, 0)
    fb = jnp.arange(f, dtype=jnp.int32)[None, :] * nbins + bins   # (n, f)
    z = jax.lax.complex(gh[:, 0].astype(jnp.float32),
                        gh[:, 1].astype(jnp.float32)).astype(jnp.complex64)
    zl = jnp.where(valid, z[None, :], 0)                   # (L, n)
    lvl_node = (jnp.arange(L, dtype=jnp.int32)[:, None] * n_nodes + node_c)
    flat = lvl_node[:, :, None] * (f * nbins) + fb[None]   # (L, n, f)
    vals = jnp.broadcast_to(zl[:, :, None], (L, n, f))
    out = jnp.zeros((L * n_nodes * f * nbins,), jnp.complex64)
    out = out.at[flat.ravel()].add(vals.ravel())
    return jnp.stack([out.real, out.imag], -1).reshape(
        L, n_nodes, f, nbins, 2).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("n_nodes", "nbins"))
def hist_levels_left_ref(bins: jax.Array, node_per_level: jax.Array,
                         gh: jax.Array, *, n_nodes: int,
                         nbins: int) -> jax.Array:
    """Oracle for the histogram-subtraction child mode.

    ``node_per_level`` holds CHILD frontier ids in ``[0, 2 * n_nodes)``
    (level-local heap ids: left child of parent ``p`` is ``2p``, right is
    ``2p + 1``).  Only rows routed LEFT (even id) contribute, keyed by
    the parent id ``child >> 1``; odd and negative ids drop out.  The
    sibling histogram is NOT computed here — subtraction growers derive
    it as ``parent - left`` from the cached previous-level panel.

    Returns:
      (n_levels, n_nodes, f, nbins, 2) float32 — ``n_nodes`` PARENT
      buckets, i.e. half the child frontier.
    """
    left = (node_per_level >= 0) & (node_per_level % 2 == 0)
    parent = jnp.where(left, node_per_level // 2, -1)
    return hist_levels_ref(bins, parent, gh, n_nodes=n_nodes, nbins=nbins)


@functools.partial(jax.jit, static_argnames=("n_nodes", "nbins"))
def hist_levels_left_packed(bins: jax.Array, node_per_level: jax.Array,
                            gh: jax.Array, *, n_nodes: int,
                            nbins: int) -> jax.Array:
    """Packed CPU scatter for the subtraction child mode (see
    :func:`hist_levels_left_ref` for the indexing contract).

    One complex64 scatter into the HALF-width parent-keyed panel: rows
    routed RIGHT (odd child id) and masked rows (negative id) get an
    out-of-range flat index, which XLA's default scatter mode DROPS —
    they never reach the read-modify-write, so the logical update count
    is ``n_left * f`` instead of ``n * f`` and the output working set is
    half the full-frontier panel's.  Matches the oracle exactly: within
    each parent bucket the surviving updates arrive in the same row
    order as the per-level scatter.
    """
    L, n = node_per_level.shape
    f = bins.shape[1]
    left = (node_per_level >= 0) & (node_per_level % 2 == 0)   # (L, n)
    parent = jnp.where(left, node_per_level // 2, 0)
    fb = jnp.arange(f, dtype=jnp.int32)[None, :] * nbins + bins   # (n, f)
    z = jax.lax.complex(gh[:, 0].astype(jnp.float32),
                        gh[:, 1].astype(jnp.float32)).astype(jnp.complex64)
    size = L * n_nodes * f * nbins
    lvl_node = (jnp.arange(L, dtype=jnp.int32)[:, None] * n_nodes + parent)
    flat = lvl_node[:, :, None] * (f * nbins) + fb[None]       # (L, n, f)
    # dropped rows point one-past-the-end (NOT -1: negative indices wrap
    # under NumPy semantics; >= size is unambiguously out of bounds)
    flat = jnp.where(left[:, :, None], flat, size)
    vals = jnp.broadcast_to(z[None, :, None], (L, n, f))
    out = jnp.zeros((size,), jnp.complex64)
    out = out.at[flat.ravel()].add(vals.ravel())
    return jnp.stack([out.real, out.imag], -1).reshape(
        L, n_nodes, f, nbins, 2).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("n_nodes", "nbins"))
def hist_packed(bins: jax.Array, node: jax.Array, gh: jax.Array, *,
                n_nodes: int, nbins: int) -> jax.Array:
    """CPU-fast histogram: grad/hess packed into one complex64 scatter.

    Bit-exact vs :func:`hist_ref` (the real/imag lanes add independently,
    in the same row order), but issues ONE scalar scatter-add per (row,
    feature) instead of a 2-wide slice update — ~1.6x faster through
    XLA:CPU's scatter path.  Single-level view of
    :func:`hist_levels_packed`; ``hist_ref`` stays the correctness
    oracle.
    """
    return hist_levels_packed(bins, node[None], gh,
                              n_nodes=n_nodes, nbins=nbins)[0]


# ---------------------------------------------------------------------------
# Batched level-synchronous forest traversal (inference hot path).
#
# A "chunk" is C stacked trees in heap SoA layout: feature (C, 2^d - 1),
# cmp (C, 2^d - 1) — raw thresholds (float32) or split bins (int32) —
# and leaf (C, 2^d).  All C trees advance one depth level per step; the
# contract is PER-TREE leaf values (n, C), so the caller controls the
# ensemble summation order (the engine accumulates in tree order, which
# makes it bit-identical to the sequential per-tree scan it replaces).
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("max_depth",))
def traverse_chunk_ref(values: jax.Array, feature: jax.Array,
                       cmp: jax.Array, leaf: jax.Array, *,
                       max_depth: int) -> jax.Array:
    """Oracle for the level-synchronous chunk traversal: a vmap over the
    per-tree descent, field-for-field the same indexing as
    ``tree._descend_raw`` / ``tree._descend_binned`` (so per-tree leaf
    values are bit-identical to the single-tree predictors).

    Args:
      values: (n, f) raw float32 features or int32 bin ids — the dtype
        carries the mode; the comparison ``value <= cmp`` is the split
        rule either way (NaN compares False, so NaN rows route RIGHT on
        the raw path).
      feature: (C, 2^max_depth - 1) int32 split features; -1 =
        passthrough (clipped to 0 for the gather, exactly like the
        single-tree descent).
      cmp: (C, 2^max_depth - 1) thresholds (float32, +inf passthrough)
        or split bins (int32, nbins-1 passthrough).
      leaf: (C, 2^max_depth) float32 leaf values.

    Returns:
      (n, C) float32 per-tree leaf values.
    """
    n = values.shape[0]

    def one_tree(fe, cm, lf):
        node = jnp.zeros((n,), jnp.int32)
        for depth in range(max_depth):
            heap = (2 ** depth - 1) + node
            fidx = fe[heap]
            cv = cm[heap]
            xv = jnp.take_along_axis(values, fidx.clip(0)[:, None], 1)[:, 0]
            node = node * 2 + jnp.where(xv <= cv, 0, 1)
        return lf[node]

    return jax.vmap(one_tree, in_axes=0, out_axes=1)(feature, cmp, leaf)


def _g(src: jax.Array, idx: jax.Array) -> jax.Array:
    """In-bounds flat gather.  ``promise_in_bounds`` skips XLA:CPU's
    per-element clamp — measurably faster at this kernel's gather
    volume (12 gathers per row-tree) — and is safe here because every
    index is in range by construction: level-local node ids live in
    [0, 2^depth), heap offsets stay below 2^max_depth - 1, and feature
    ids from build_tree are in [-1, f) and clipped to 0 before use."""
    return src.at[idx].get(mode="promise_in_bounds", unique_indices=False)


@functools.partial(jax.jit, static_argnames=("max_depth",))
def traverse_chunk_packed(values: jax.Array, feature: jax.Array,
                          cmp: jax.Array, leaf: jax.Array, *,
                          max_depth: int) -> jax.Array:
    """CPU-fast chunk traversal: the (feature, cmp) node record is packed
    into one complex64 array, so every level costs ONE fused gather over
    the whole flattened (tree, node) heap for both fields — plus one
    row-wise feature-value gather — instead of the per-tree loop's 2C
    small gathers.  Three extra CPU tweaks, each worth real wall-clock
    at the 500x6 bench: level 0 reads the root record with a slice
    instead of a gather (every row is at node 0), all gathers are flat
    1-D with precomputed row/tree offsets (XLA lowers these leaner than
    the fancy-indexing dimension_numbers), and bounds clamping is
    skipped via promise_in_bounds (see :func:`_g`).

    Bit-exact vs :func:`traverse_chunk_ref`: the comparison runs in
    float32 on both paths (bin ids and split bins are small ints, exact
    in f32; feature ids < 2^24 survive the imag lane round-trip), and
    the -1 passthrough feature is clipped to 0 before the value gather,
    so even NaN rows take identical routes.

    Same signature/returns as :func:`traverse_chunk_ref`.
    """
    n, f = values.shape
    C, n_inner = feature.shape
    n_leaves = leaf.shape[1]
    if max_depth == 0 or n_inner == 0:
        return jnp.broadcast_to(leaf[:, 0][None, :], (n, C))
    rec = jax.lax.complex(cmp.astype(jnp.float32),
                          feature.astype(jnp.float32))
    rec = rec.astype(jnp.complex64).ravel()          # (C * n_inner,)
    tree_off = (jnp.arange(C, dtype=jnp.int32) * n_inner)[None, :]
    row_off = (jnp.arange(n, dtype=jnp.int32) * f)[:, None]
    vflat = values.astype(jnp.float32).ravel()
    # level 0: every row sits at the root — slice the record, no gather
    f0 = feature[:, 0].clip(0)                       # (C,)
    c0 = cmp[:, 0].astype(jnp.float32)
    xv = _g(vflat, row_off + f0[None, :])
    node = jnp.where(xv <= c0[None, :], 0, 1).astype(jnp.int32)
    for depth in range(1, max_depth):
        r = _g(rec, tree_off + (2 ** depth - 1) + node)   # both fields
        fidx = r.imag.astype(jnp.int32)
        xv = _g(vflat, row_off + fidx.clip(0))
        node = node * 2 + jnp.where(xv <= r.real, 0, 1)
    leaf_off = (jnp.arange(C, dtype=jnp.int32) * n_leaves)[None, :]
    return _g(leaf.ravel(), leaf_off + node)


@functools.partial(jax.jit, static_argnames=())
def _score(g, h, l2):
    return (g * g) / (h + l2)


@functools.partial(jax.jit, static_argnames=())
def split_gain_ref(hist: jax.Array, *, l2: float = 1.0, gamma: float = 0.0,
                   min_child_weight: float = 1e-6):
    """Best gain / split-bin per (node, feature) — oracle for split_gain."""
    g = hist[..., 0]
    h = hist[..., 1]
    gl = jnp.cumsum(g, axis=2)
    hl = jnp.cumsum(h, axis=2)
    gt = gl[..., -1:]
    ht = hl[..., -1:]
    gr = gt - gl
    hr = ht - hl
    gain = 0.5 * (_score(gl, hl, l2) + _score(gr, hr, l2)
                  - _score(gt, ht, l2)) - gamma
    nbins = gain.shape[2]
    pos = jnp.arange(nbins)
    ok = (hl >= min_child_weight) & (hr >= min_child_weight) \
        & (pos < nbins - 1)
    gain = jnp.where(ok, gain, -jnp.inf)
    return jnp.max(gain, axis=2), jnp.argmax(gain, axis=2).astype(jnp.int32)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """Naive fp32 attention with GQA + sliding window (oracle)."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    qpos = jnp.arange(sq)[:, None] + (sk - sq)  # right-aligned (cache case)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
