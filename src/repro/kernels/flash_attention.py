"""Pallas TPU kernel: blockwise (flash) attention with GQA + sliding window.

Used by the transformer substrate's prefill path on real TPUs.  Online
softmax over KV blocks; grid (batch*kv_heads*group, q_blocks, kv_blocks)
with fp32 scratch accumulators in VMEM.  Q blocks and KV blocks are
(block_q, d) / (block_k, d) tiles — multiples of 128 keep the MXU happy.

The jnp oracle lives in ref.py; the jit'd public wrapper in ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, n_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0].astype(jnp.float32)            # (bk, d)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                          # (bq,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)

    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """Blockwise attention.

    Args:
      q: (batch, q_heads, seq, d) queries.
      k, v: (batch, kv_heads, seq, d); q_heads % kv_heads == 0 (GQA).
      window: 0 = full context; else sliding window of that many keys.

    Returns:
      (batch, q_heads, seq, d) in q's dtype.
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk)

    scale = 1.0 / (d ** 0.5)
    nq = sq // block_q
    nk = sk // block_k

    qf = q.reshape(b * hkv * g, sq, d)
    kf = k.reshape(b * hkv, sk, d)
    vf = v.reshape(b * hkv, sk, d)

    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_kv_blocks=nk)

    out = pl.pallas_call(
        kern,
        grid=(b * hkv * g, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, qi, ki, g=g: (bh // g, ki, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, qi, ki, g=g: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv * g, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, d)
