"""Pallas TPU kernels for the compute hot-spots.

hist            — gradient/hessian histogram as one-hot MXU matmul
split_gain      — best-split scan over histogram bins
flash_attention — blockwise attention (GQA + sliding window)

Call through :mod:`repro.kernels.ops`; oracles in :mod:`repro.kernels.ref`.
"""

from . import ops, ref

__all__ = ["ops", "ref"]
