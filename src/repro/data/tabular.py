"""Synthetic tabular datasets standing in for the paper's seven benchmarks.

The paper's datasets (SUSY, Higgs, Hepmass, Wiretap/Mirai, PJM/Dominion)
are not available offline; these generators reproduce their *shape class*
(wide noisy classification, physics-style mixtures, autocorrelated
regression series) at configurable row counts so Table-2-style claims
(random ≈ quantile accuracy, T(S) < T(Q)) can be validated.
"""

from __future__ import annotations

import numpy as np


def gaussian_classification(n: int, f: int, seed: int = 0,
                            sep: float = 1.2, flip: float = 0.05):
    """Two anisotropic Gaussian mixtures + label noise (SUSY/Higgs-like)."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 2, n)
    means = rng.normal(0, sep, (2, f))
    scales = rng.uniform(0.5, 2.0, (2, f))
    x = rng.normal(means[y], scales[y]).astype(np.float32)
    # a few non-linear interaction features (physics-derived columns)
    k = max(2, f // 4)
    x[:, :k] = x[:, :k] * x[:, k:2 * k] if 2 * k <= f else x[:, :k]
    noise = rng.random(n) < flip
    y = np.where(noise, 1 - y, y).astype(np.float32)
    return x, y


def friedman1(n: int, f: int = 10, seed: int = 0, noise: float = 1.0):
    """Friedman-1 regression (nonlinear + interactions)."""
    rng = np.random.default_rng(seed)
    x = rng.random((n, max(f, 5))).astype(np.float32)
    y = (10 * np.sin(np.pi * x[:, 0] * x[:, 1]) + 20 * (x[:, 2] - 0.5) ** 2
         + 10 * x[:, 3] + 5 * x[:, 4] + rng.normal(0, noise, n))
    return x[:, :f], y.astype(np.float32)


def ar1_series(n: int, f: int = 10, seed: int = 0, rho: float = 0.98):
    """AR(1) energy-consumption-style series with lag features (PJM-like).

    Non-iid by construction — the paper calls out random sampling handling
    non-iid data; rows are time-ordered, so worker shards see different
    regimes.
    """
    rng = np.random.default_rng(seed)
    e = rng.normal(0, 1, n + f)
    s = np.zeros(n + f)
    for t in range(1, n + f):
        s[t] = rho * s[t - 1] + e[t]
    s = s + 0.2 * np.sin(np.arange(n + f) * 2 * np.pi / 24)   # daily cycle
    s = 100.0 + 10.0 * s      # positive, load-like level (MAPE-meaningful)
    x = np.stack([s[i:i + n] for i in range(f)], 1).astype(np.float32)
    y = s[f:f + n].astype(np.float32)
    return x, y


_REGISTRY = {
    # name -> (generator, task, n_features)  [paper analogue]
    "wiretap-like": (lambda n, s: gaussian_classification(n, 115, s), "class", 115),
    "susy-like": (lambda n, s: gaussian_classification(n, 18, s), "class", 18),
    "higgs-like": (lambda n, s: gaussian_classification(n, 28, s), "class", 28),
    "friedman": (lambda n, s: friedman1(n, 10, s), "reg", 10),
    "pjm-like": (lambda n, s: ar1_series(n, 10, s), "reg", 10),
}

DATASET_NAMES = list(_REGISTRY)


def make_dataset(name: str, n_train: int, n_test: int, seed: int = 0):
    gen, task, _ = _REGISTRY[name]
    x, y = gen(n_train + n_test, seed)
    return (x[:n_train], y[:n_train], x[n_train:], y[n_train:], task)
