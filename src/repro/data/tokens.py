"""Deterministic synthetic LM token pipeline (sharded, stateless).

Batches are a pure function of (seed, step), so every data-parallel worker
can materialise its own shard without coordination — the same
local-sample-then-share philosophy as the paper's Algorithm 1, applied to
the data pipeline.  Tokens follow a Zipfian marginal with short-range
structure (repeated n-grams) so cross-entropy is learnable.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        """Full global batch {tokens: (B, S)} for a step (host or jit)."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        b, s, v = self.global_batch, self.seq_len, self.vocab_size
        # Zipf-ish marginal via exponential transform of uniforms
        u = jax.random.uniform(key, (b, s), minval=1e-6, maxval=1.0)
        toks = jnp.floor((v - 1) * u ** 3.0).astype(jnp.int32)
        # inject learnable structure: every 2nd token repeats previous
        rep = jnp.roll(toks, 1, axis=1)
        mask = (jnp.arange(s)[None, :] % 2).astype(bool)
        toks = jnp.where(mask, rep, toks)
        return {"tokens": toks}

    def shard_at(self, step: int, worker: int, n_workers: int) -> dict:
        """Local shard of the global batch for one data-parallel worker."""
        full = self.batch_at(step)
        per = self.global_batch // n_workers
        return {k: v[worker * per:(worker + 1) * per] for k, v in full.items()}
