"""Data substrate: synthetic tabular generators + LM token pipeline."""

from .tabular import (friedman1, gaussian_classification, ar1_series,
                      make_dataset)
from .tokens import TokenPipeline

__all__ = ["friedman1", "gaussian_classification", "ar1_series",
           "make_dataset", "TokenPipeline"]
