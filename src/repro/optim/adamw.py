"""AdamW with global-norm clipping and cosine schedule (self-contained).

State layout mirrors the params pytree: {'m': tree, 'v': tree,
'step': scalar}.  The launcher shards m/v with the ZeRO-1 rule (see
launch/shardings.py): same spec as the param plus the 'data' axis on the
largest divisible unsharded dimension.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def cosine_lr(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * jnp.clip(t, 0.0, 1.0)))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)

    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vh = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        newp = p.astype(jnp.float32) - lr * (
            mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
