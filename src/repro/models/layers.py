"""Shared neural layers: norms, MLPs, embeddings, RoPE, losses.

Functional style: ``init_*`` returns a params dict; ``apply`` functions are
pure.  Params are stored fp32; matmuls run in ``compute_dtype`` (bf16 on
TPU) with fp32 accumulation where it matters.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .sharding import constrain

COMPUTE_DTYPE = jnp.bfloat16


def _dense_init(key, shape, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# linear / embedding
# ---------------------------------------------------------------------------

def init_linear(key, d_in: int, d_out: int, bias: bool = False):
    p = {"w": _dense_init(key, (d_in, d_out))}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def linear(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_embedding(key, vocab: int, d: int):
    # 0.02 (GPT-2 style) keeps tied-unembedding logits O(1) at init
    return {"table": _dense_init(key, (vocab, d), scale=0.02)}


def embed(p, tokens, dtype=COMPUTE_DTYPE):
    return p["table"].astype(dtype)[tokens]


def unembed(p, x):
    """Logits against the (possibly tied) embedding table."""
    return x @ p["table"].astype(x.dtype).T


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, mlp_type: str):
    ks = jax.random.split(key, 3)
    if mlp_type == "swiglu":
        return {"wi": _dense_init(ks[0], (d_model, d_ff)),
                "wg": _dense_init(ks[1], (d_model, d_ff)),
                "wo": _dense_init(ks[2], (d_ff, d_model))}
    if mlp_type == "gelu":
        return {"wi": _dense_init(ks[0], (d_model, d_ff)),
                "wo": _dense_init(ks[2], (d_ff, d_model))}
    raise ValueError(mlp_type)


def mlp(p, x, mlp_type: str):
    h = x @ p["wi"].astype(x.dtype)
    if mlp_type == "swiglu":
        h = jax.nn.silu(h) * (x @ p["wg"].astype(x.dtype))
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, *(("batch", "seq", "ff") if h.ndim == 3
                       else ("batch", "ff")))
    return h @ p["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                                # (d/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv   # (..., seq, d/2)
    cos = jnp.cos(ang)[..., None, :]                          # (..., seq, 1, d/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: jax.Array | None = None) -> jax.Array:
    """Mean token cross-entropy; logits (..., V) fp32-accumulated."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def softmax_xent_chunked(table: jax.Array, x: jax.Array, labels: jax.Array,
                         chunk: int = 256,
                         scan_chunks: bool = True) -> jax.Array:
    """Cross-entropy against a tied embedding table WITHOUT materialising
    the full (B, S, V) logits: scan over seq chunks, rematerialising each
    chunk's logits in the backward pass.  Peak logits memory drops from
    S/chunk x to one chunk (the V=150k vocabularies otherwise dominate the
    training step's temp memory).
    """
    b, s, d = x.shape
    c = chunk
    while s % c:
        c -= 1
    nc = s // c
    xs = jnp.moveaxis(x.reshape(b, nc, c, d), 1, 0)          # (nc,B,c,D)
    ls = jnp.moveaxis(labels.reshape(b, nc, c), 1, 0)        # (nc,B,c)

    @jax.checkpoint
    def body(acc, inp):
        xc, lc = inp
        logits = (xc @ table.astype(xc.dtype).T).astype(jnp.float32)
        logits = constrain(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], -1)[..., 0]
        return acc + jnp.sum(lse - ll), None

    if scan_chunks:
        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls))
    else:
        total = jnp.zeros((), jnp.float32)
        for i in range(nc):
            total, _ = body(total, (xs[i], ls[i]))
    return total / (b * s)
