"""State-space / recurrent blocks: Mamba2 (SSD), mLSTM, sLSTM.

Mamba2 and mLSTM share one computational core, *chunked decay attention*:

    S_t = exp(ld_t) * S_{t-1} + k_t v_t^T          (state (N, P) per head)
    y_t = q_t @ S_t

computed chunk-parallel (Mamba2's SSD block decomposition): a quadratic
masked intra-chunk part that maps onto the MXU, plus an inter-chunk scan
carrying S.  Mapping:

  Mamba2:  q=C, k=B, v=dt*x, ld = a*dt  (a = -exp(A_log) < 0)
  mLSTM :  q=q/sqrt(dk), k=i_t*k_t, v=[v, 1], ld = logsigmoid(f_logit);
           the appended ones-column makes the normalizer n_t ride along in
           the same state, y = num / max(|den|, 1)  (xLSTM eq. 21-24;
           sigmoid input gate per the mLSTM-sig variant — DESIGN.md).

sLSTM is inherently sequential (scalar gates with recurrent h feedback) —
lax.scan over time with the exp-gate stabilizer m_t (xLSTM eq. 15-17).

Simplifications vs the releases (noted in DESIGN.md): no causal conv1d
frontends, ngroups=1 for B/C, no per-invocation LoRA on shared blocks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .sharding import constrain


# ---------------------------------------------------------------------------
# shared core: chunked decay attention
# ---------------------------------------------------------------------------

def chunked_decay_attention(q, k, v, logdecay, chunk: int, state=None,
                            scan_chunks: bool = True,
                            compute_dtype=jnp.float32):
    """Chunk-parallel linear attention with per-step decay.

    Args:
      q, k: (B, S, G, N) — G head GROUPS.  Mamba2's shared B/C (ngroups=1)
        passes G=1 and is never broadcast across heads (a §Perf change:
        the naive broadcast materialised (B,S,H,N) fp32 copies of q and
        k — 5.4 GB/layer for zamba2 — for tensors that carry no per-head
        information).  mLSTM passes G=H.
      v: (B, S, H, P); logdecay: (B, S, H) (<= 0); H % G == 0.
      chunk: chunk length (S % chunk == 0).
      state: optional initial (B, H, N, P).

    Returns:
      y: (B, S, H, P), final state (B, H, N, P).  fp32 accumulation.
    """
    b, s, g, n = q.shape
    h = v.shape[2]
    p = v.shape[-1]
    assert s % chunk == 0, (s, chunk)
    assert h % g == 0, (h, g)
    hg = h // g
    nc = s // chunk

    qf = q.reshape(b, nc, chunk, g, n)
    kf = k.reshape(b, nc, chunk, g, n)
    vf = v.reshape(b, nc, chunk, g, hg, p)
    ld = logdecay.astype(jnp.float32).reshape(b, nc, chunk, g, hg)

    if state is None:
        state = jnp.zeros((b, g, hg, n, p), jnp.float32)
    else:
        state = state.reshape(b, g, hg, n, p)

    # move chunk axis to front for scan
    qf, kf, vf, ld = (jnp.moveaxis(a, 1, 0) for a in (qf, kf, vf, ld))

    idx = jnp.arange(chunk)
    tri = idx[:, None] >= idx[None, :]                       # (L, M) lower

    cd = compute_dtype

    def body(S, inp):
        qc, kc, vc, ldc = inp       # (B,L,G,N) (B,L,G,N) (B,L,G,Hg,P) (B,L,G,Hg)
        cum = jnp.cumsum(ldc, axis=1)                        # (B,L,G,Hg)
        total = cum[:, -1:]                                  # (B,1,G,Hg)
        # group-shared part of the scores: (q_i . k_j) per group
        sc = jnp.einsum("blgn,bmgn->bglm", qc.astype(cd), kc.astype(cd),
                        preferred_element_type=jnp.float32)  # (B,G,L,M)
        # per-head decay factor exp(cum_i - cum_j), masked lower-triangular
        cum_h = jnp.moveaxis(cum, 1, 3)                      # (B,G,Hg,L)
        dec = jnp.exp(cum_h[..., :, None] - cum_h[..., None, :])
        scores = (sc[:, :, None] * dec * tri).astype(cd)     # (B,G,Hg,L,M)
        y_intra = jnp.einsum("bghlm,bmghp->blghp", scores, vc.astype(cd),
                             preferred_element_type=jnp.float32)
        # inter-chunk: exp(cum_i) * (q_i @ S_prev)   (exp applied on the
        # OUTPUT so group-shared q is never expanded per head)
        qs = jnp.einsum("blgn,bghnp->blghp", qc.astype(cd), S.astype(cd),
                        preferred_element_type=jnp.float32)
        y_inter = qs * jnp.exp(cum)[..., None]
        # state update: exp applied on the per-head v side, k stays shared
        v_dec = vc.astype(jnp.float32) * jnp.exp(total - cum)[..., None]
        S_new = jnp.exp(total)[:, 0, ..., None, None] * S + \
            jnp.einsum("bmgn,bmghp->bghnp", kc.astype(cd),
                       v_dec.astype(cd),
                       preferred_element_type=jnp.float32)
        return S_new, y_intra + y_inter

    if scan_chunks:
        state, y = jax.lax.scan(body, state, (qf, kf, vf, ld))
    else:
        # unrolled (dry-run cost measurement: while bodies count once)
        ys = []
        for i in range(nc):
            state, yi = body(state, (qf[i], kf[i], vf[i], ld[i]))
            ys.append(yi)
        y = jnp.stack(ys)
    y = jnp.moveaxis(y, 0, 1).reshape(b, s, h, p)
    return y, state.reshape(b, h, n, p)


def decay_attention_step(q, k, v, logdecay, state):
    """Single-token recurrence (decode).  q,k (B,H,N), v (B,H,P),
    logdecay (B,H), state (B,H,N,P) -> (y (B,H,P), new state)."""
    state = jnp.exp(logdecay.astype(jnp.float32))[..., None, None] * state \
        + k.astype(jnp.float32)[..., :, None] * v.astype(jnp.float32)[..., None, :]
    y = jnp.einsum("bhn,bhnp->bhp", q.astype(jnp.float32), state)
    return y, state


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------

def mamba2_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    return d_inner, nheads


def init_mamba2(key, cfg):
    d = cfg.d_model
    d_inner, nh = mamba2_dims(cfg)
    n = cfg.ssm_state
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_inner + 2 * n + nh          # z, x, B, C, dt
    return {
        "ln": layers.init_rmsnorm(d),
        "in_proj": layers.init_linear(ks[0], d, proj_out),
        "out_proj": layers.init_linear(ks[1], d_inner, d),
        "A_log": jnp.zeros((nh,), jnp.float32),            # a = -exp(0) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),     # softplus(-2)≈0.13
    }


def _mamba2_project(p, cfg, x):
    d_inner, nh = mamba2_dims(cfg)
    n = cfg.ssm_state
    x = layers.rmsnorm(p["ln"], x)               # pre-norm (residual outside)
    z, xh, bmat, cmat, dt = jnp.split(
        layers.linear(p["in_proj"], x),
        [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,nh)
    a = -jnp.exp(p["A_log"])                                       # (nh,)
    return z, xh, bmat, cmat, dt, a


def mamba2_layer(p, cfg, x, state=None):
    """x (B,S,D) -> (y (B,S,D), final ssm state)."""
    b, s, d = x.shape
    d_inner, nh = mamba2_dims(cfg)
    ph = cfg.ssm_head_dim
    z, xh, bmat, cmat, dt, a = _mamba2_project(p, cfg, x)
    xh = xh.reshape(b, s, nh, ph)
    # B/C shared across heads (ngroups=1): pass as a single GROUP — the
    # chunked core never broadcasts them per head (§Perf)
    k = bmat[:, :, None, :]                      # (B,S,1,N)
    q = cmat[:, :, None, :]
    v = xh * dt[..., None].astype(xh.dtype)
    ld = a[None, None, :] * dt                                # (B,S,nh) <= 0
    y, st = chunked_decay_attention(
        q, k, v, ld, min(cfg.ssm_chunk, s), state,
        scan_chunks=cfg.scan_chunks,
        compute_dtype=(jnp.bfloat16 if cfg.ssm_compute_dtype == "bf16"
                       else jnp.float32))
    y = y.astype(x.dtype) + p["D"].astype(x.dtype)[None, None, :, None] * xh
    y = y.reshape(b, s, d_inner) * jax.nn.silu(z)
    return layers.linear(p["out_proj"], y), st


def mamba2_step(p, cfg, x, state):
    """Decode: x (B,1,D), state (B,H,N,P)."""
    b = x.shape[0]
    d_inner, nh = mamba2_dims(cfg)
    ph = cfg.ssm_head_dim
    z, xh, bmat, cmat, dt, a = _mamba2_project(p, cfg, x)
    xh = xh.reshape(b, nh, ph)
    k = jnp.broadcast_to(bmat[:, 0, None, :], (b, nh, cfg.ssm_state))
    q = jnp.broadcast_to(cmat[:, 0, None, :], (b, nh, cfg.ssm_state))
    dt1 = dt[:, 0]                                            # (B,nh)
    v = xh * dt1[..., None].astype(xh.dtype)
    y, state = decay_attention_step(q, k, v, a[None] * dt1, state)
    y = y.astype(x.dtype) + p["D"].astype(x.dtype)[None, :, None] * xh
    y = y.reshape(b, 1, d_inner) * jax.nn.silu(z)
    return layers.linear(p["out_proj"], y), state


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory block)
# ---------------------------------------------------------------------------

def mlstm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    dh = d_inner // cfg.n_heads
    return d_inner, dh


def init_mlstm(key, cfg):
    d = cfg.d_model
    d_inner, dh = mlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "ln": layers.init_rmsnorm(d),
        "up": layers.init_linear(ks[0], d, 2 * d_inner),       # [xh, z]
        "wq": layers.init_linear(ks[1], d_inner, d_inner),
        "wk": layers.init_linear(ks[2], d_inner, d_inner),
        "wv": layers.init_linear(ks[3], d_inner, d_inner),
        "wif": layers.init_linear(ks[4], d_inner, 2 * cfg.n_heads),
        "norm": layers.init_rmsnorm(d_inner),
        "down": layers.init_linear(ks[5], d_inner, d),
    }


def _mlstm_project(p, cfg, x):
    b = x.shape[0]
    s = x.shape[1]
    d_inner, dh = mlstm_dims(cfg)
    h = cfg.n_heads
    x = layers.rmsnorm(p["ln"], x)               # pre-norm (residual outside)
    xh, z = jnp.split(layers.linear(p["up"], x), 2, axis=-1)
    q = layers.linear(p["wq"], xh).reshape(b, s, h, dh) / (dh ** 0.5)
    k = layers.linear(p["wk"], xh).reshape(b, s, h, dh)
    v = layers.linear(p["wv"], xh).reshape(b, s, h, dh)
    gates = layers.linear(p["wif"], xh).astype(jnp.float32)
    ig, fg = jnp.split(gates, 2, axis=-1)                      # (B,S,H)
    i_t = jax.nn.sigmoid(ig)
    ld = jax.nn.log_sigmoid(fg)
    return xh, z, q, k * i_t[..., None].astype(k.dtype), v, ld


def mlstm_layer(p, cfg, x, state=None):
    b, s, d = x.shape
    d_inner, dh = mlstm_dims(cfg)
    xh, z, q, k, v, ld = _mlstm_project(p, cfg, x)
    # normalizer ridden along as an extra value column
    ones = jnp.ones((*v.shape[:-1], 1), v.dtype)
    vn = jnp.concatenate([v, ones], -1)
    yn, st = chunked_decay_attention(
        q, k, vn, ld, min(cfg.ssm_chunk, s), state,
        scan_chunks=cfg.scan_chunks,
        compute_dtype=(jnp.bfloat16 if cfg.ssm_compute_dtype == "bf16"
                       else jnp.float32))
    num, den = yn[..., :dh], yn[..., dh:]
    y = num / jnp.maximum(jnp.abs(den), 1.0)
    y = y.astype(x.dtype).reshape(b, s, d_inner)
    y = layers.rmsnorm(p["norm"], y) * jax.nn.silu(z)
    return layers.linear(p["down"], y), st


def mlstm_step(p, cfg, x, state):
    b = x.shape[0]
    d_inner, dh = mlstm_dims(cfg)
    xh, z, q, k, v, ld = _mlstm_project(p, cfg, x)
    ones = jnp.ones((b, 1, cfg.n_heads, 1), v.dtype)
    vn = jnp.concatenate([v, ones], -1)
    yn, state = decay_attention_step(q[:, 0], k[:, 0], vn[:, 0], ld[:, 0],
                                     state)
    num, den = yn[..., :dh], yn[..., dh:]
    y = (num / jnp.maximum(jnp.abs(den), 1.0)).astype(x.dtype)
    y = y.reshape(b, 1, d_inner)
    y = layers.rmsnorm(p["norm"], y) * jax.nn.silu(z)
    return layers.linear(p["down"], y), state


def mlstm_state_shape(cfg, batch: int):
    d_inner, dh = mlstm_dims(cfg)
    return (batch, cfg.n_heads, dh, dh + 1)


def mamba2_state_shape(cfg, batch: int):
    _, nh = mamba2_dims(cfg)
    return (batch, nh, cfg.ssm_state, cfg.ssm_head_dim)


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory, sequential)
# ---------------------------------------------------------------------------

def init_slstm(key, cfg):
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 3)
    return {
        "ln": layers.init_rmsnorm(d),
        # input projections for gates i,f,z,o
        "wx": layers.init_linear(ks[0], d, 4 * d),
        # per-head recurrent weights (block-diagonal)
        "r": jax.random.normal(ks[1], (h, dh, 4 * dh), jnp.float32) * (dh ** -0.5),
        "down": layers.init_linear(ks[2], d, d),
    }


def _slstm_scan(p, cfg, gx, state):
    """gx (B,S,H,4*dh) precomputed input gates; sequential over S."""
    b, s, h, _ = gx.shape
    dh = cfg.d_model // h
    c0, n0, h0, m0 = state

    def step(carry, g_t):
        c, n, hh, m = carry                                     # (B,H,dh) / (B,H)
        rec = jnp.einsum("bhd,hde->bhe", hh, p["r"])            # (B,H,4dh)
        g = g_t.astype(jnp.float32) + rec
        gi, gf, gz, go = jnp.split(g, 4, axis=-1)               # (B,H,dh)
        # scalar-per-head gates (mean over dh for i/f keeps shapes simple)
        logi = jnp.mean(gi, -1)
        logf = jnp.mean(gf, -1)                                  # pre-exp
        m_new = jnp.maximum(logf + m, logi)                      # stabilizer
        i_t = jnp.exp(logi - m_new)[..., None]
        f_t = jnp.exp(logf + m - m_new)[..., None]
        z_t = jnp.tanh(gz)
        o_t = jax.nn.sigmoid(go)
        c = f_t * c + i_t * z_t
        n = f_t * n + i_t
        hh = o_t * c / jnp.maximum(n, 1.0)
        return (c, n, hh, m_new), hh

    gx_t = jnp.moveaxis(gx, 1, 0)                                # (S,B,H,4dh)
    (c, n, hh, m), ys = jax.lax.scan(step, (c0, n0, h0, m0), gx_t)
    return jnp.moveaxis(ys, 0, 1), (c, n, hh, m)                 # (B,S,H,dh)


def slstm_layer(p, cfg, x, state=None):
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h
    if state is None:
        state = slstm_init_state(cfg, b)
    x = layers.rmsnorm(p["ln"], x)               # pre-norm (residual outside)
    gx = layers.linear(p["wx"], x).reshape(b, s, h, 4 * dh)
    y, state = _slstm_scan(p, cfg, gx, state)
    y = y.astype(x.dtype).reshape(b, s, d)
    return layers.linear(p["down"], y), state


def slstm_step(p, cfg, x, state):
    y, state = slstm_layer(p, cfg, x, state)
    return y, state


def slstm_init_state(cfg, batch: int):
    h = cfg.n_heads
    dh = cfg.d_model // h
    z = jnp.zeros((batch, h, dh), jnp.float32)
    zm = jnp.full((batch, h), -1e30, jnp.float32)
    return (z, z, z, zm)
