"""Model assembly: init / train forward / prefill / decode for all families.

Families and their layer stacks:

  dense | vlm      uniform [attn + MLP] decoder layers     -> one lax.scan
  moe              uniform [attn + MoE] decoder layers     -> one lax.scan
  ssm (xlstm)      groups of (slstm_every-1) mLSTM + 1 sLSTM -> scan of scans
  hybrid (zamba2)  groups of [shared attn+MLP] + attn_every Mamba2
                   (attention params SHARED across groups — the Zamba trick)
  audio (whisper)  enc-dec: bidirectional encoder over stub frame
                   embeddings, causal decoder with cross-attention

Layer parameters are stacked on a leading axis and consumed by lax.scan —
this keeps HLO size O(1) in depth (critical for the 88-layer configs) and
is also what makes the pjit sharding rules uniform.  ``jax.checkpoint``
(remat) wraps each scanned block when cfg.remat.

Decode ("serve_step") processes ONE new token against a KV cache /
recurrent state, matching the decode_32k / long_500k dry-run shapes.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import attention as attn_lib
from . import layers, moe as moe_lib, ssm as ssm_lib
from .sharding import constrain

Params = Any


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_decoder_layer(key, cfg, is_moe: bool):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": layers.init_rmsnorm(cfg.d_model),
        "attn": attn_lib.init_attention(ks[0], cfg),
        "ln2": layers.init_rmsnorm(cfg.d_model),
    }
    if is_moe:
        p["moe"] = moe_lib.init_moe(ks[1], cfg)
    else:
        p["mlp"] = layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_type)
    return p


def _stack(key, n, fn):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(cfg, key) -> Params:
    ks = jax.random.split(key, 8)
    p: dict = {"embed": layers.init_embedding(ks[0], cfg.vocab_size,
                                              cfg.d_model),
               "ln_f": layers.init_rmsnorm(cfg.d_model)}
    if not cfg.tie_embeddings:
        p["unembed"] = layers.init_linear(ks[7], cfg.d_model, cfg.vocab_size)

    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        p["layers"] = _stack(
            ks[1], cfg.n_layers,
            lambda k: _init_decoder_layer(k, cfg, fam == "moe"))
        if fam == "vlm":
            p["patch_proj"] = layers.init_linear(ks[2], cfg.d_model,
                                                 cfg.d_model)
    elif fam == "ssm":
        n_grp = cfg.n_layers // cfg.slstm_every
        n_ml = cfg.slstm_every - 1
        p["mlstm"] = _stack(
            ks[1], n_grp,
            lambda k: jax.vmap(lambda k2: ssm_lib.init_mlstm(k2, cfg))(
                jax.random.split(k, n_ml)))
        p["slstm"] = _stack(ks[2], n_grp,
                            lambda k: ssm_lib.init_slstm(k, cfg))
    elif fam == "hybrid":
        n_grp = cfg.n_layers // cfg.attn_every
        p["mamba"] = _stack(
            ks[1], n_grp,
            lambda k: jax.vmap(lambda k2: ssm_lib.init_mamba2(k2, cfg))(
                jax.random.split(k, cfg.attn_every)))
        # ONE shared attention+MLP block (Zamba)
        p["shared_attn"] = _init_decoder_layer(ks[2], cfg, False)
    elif fam == "audio":
        p["enc_layers"] = _stack(
            ks[1], cfg.n_encoder_layers,
            lambda k: _init_decoder_layer(k, cfg, False))
        p["dec_layers"] = _stack(
            ks[2], cfg.n_layers,
            lambda k: _init_decoder_layer(k, cfg, False))
        p["cross_layers"] = _stack(
            ks[3], cfg.n_layers,
            lambda k: {"ln": layers.init_rmsnorm(cfg.d_model),
                       "attn": attn_lib.init_attention(k, cfg, cross=True)})
        p["ln_enc"] = layers.init_rmsnorm(cfg.d_model)
        p["frame_proj"] = layers.init_linear(ks[4], cfg.d_model, cfg.d_model)
    else:
        raise ValueError(fam)
    return p


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _decoder_block(lp, cfg, x, positions, *, window=0, is_moe=False,
                   causal=True):
    h = attn_lib.attention(lp["attn"], cfg, layers.rmsnorm(lp["ln1"], x),
                           positions, causal=causal, window=window)
    x = x + h
    x = constrain(x, "batch", "seq", "embed")
    aux = jnp.zeros((), jnp.float32)
    z = layers.rmsnorm(lp["ln2"], x)
    if is_moe:
        y, aux = moe_lib.moe_layer(lp["moe"], cfg, z)
    else:
        y = layers.mlp(lp["mlp"], z, cfg.mlp_type)
    x = x + y
    return constrain(x, "batch", "seq", "embed"), aux


def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat else fn


def _scan(cfg, body, carry, xs):
    """lax.scan over stacked layer params, or a Python unroll when
    cfg.scan_layers=False (dry-run cost measurement; see configs/base)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


# ---------------------------------------------------------------------------
# forward (train / prefill, full sequence)
# ---------------------------------------------------------------------------

def _backbone(params, cfg, x, positions, *, window=0):
    """Full-sequence pass through the layer stack. x (B,S,D)."""
    fam = cfg.family
    aux0 = jnp.zeros((), jnp.float32)

    if fam in ("dense", "vlm", "moe"):
        def body(carry, lp):
            x, aux = carry
            x, a = _decoder_block(lp, cfg, x, positions, window=window,
                                  is_moe=(fam == "moe"))
            return (x, aux + a), None
        (x, aux), _ = _scan(cfg, _maybe_remat(body, cfg), (x, aux0),
                                   params["layers"])
        return x, aux

    if fam == "ssm":
        def group(carry, lps):
            x, aux = carry
            ml_stack, sl = lps

            def ml_body(xc, lp):
                y, _ = ssm_lib.mlstm_layer(lp, cfg, xc)
                return xc + y, None
            x, _ = _scan(cfg, _maybe_remat(ml_body, cfg), x, ml_stack)
            y, _ = ssm_lib.slstm_layer(sl, cfg, x)
            return (x + y, aux), None
        (x, aux), _ = _scan(cfg, group, (x, aux0),
                                   (params["mlstm"], params["slstm"]))
        return x, aux

    if fam == "hybrid":
        shared = params["shared_attn"]

        def group(carry, mstack):
            x, aux = carry
            x, _ = _decoder_block(shared, cfg, x, positions, window=window)

            def m_body(xc, lp):
                y, _ = ssm_lib.mamba2_layer(lp, cfg, xc)
                return xc + y, None
            x, _ = _scan(cfg, _maybe_remat(m_body, cfg), x, mstack)
            return (x, aux), None
        (x, aux), _ = _scan(cfg, group, (x, aux0), params["mamba"])
        return x, aux

    raise ValueError(fam)


def _sinusoidal(n, d):
    pos = jnp.arange(n)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10_000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)


def _encode_audio(params, cfg, frames):
    """frames: (B, F, D) stub embeddings -> encoder output (B, F, D)."""
    x = layers.linear(params["frame_proj"],
                      frames.astype(layers.COMPUTE_DTYPE))
    x = x + _sinusoidal(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])

    def body(carry, lp):
        x, = carry
        x, _ = _decoder_block(lp, cfg, x, pos, causal=False)
        return (x,), None
    (x,), _ = _scan(cfg, _maybe_remat(body, cfg), (x,),
                           params["enc_layers"])
    return layers.rmsnorm(params["ln_enc"], x)


def _decode_audio_full(params, cfg, x, positions, enc_out):
    enc_pos = jnp.broadcast_to(jnp.arange(enc_out.shape[1])[None],
                               enc_out.shape[:2])

    def body(carry, lps):
        x, = carry
        lp, cp = lps
        x, _ = _decoder_block(lp, cfg, x, positions)
        h = attn_lib.attention(cp["attn"], cfg,
                               layers.rmsnorm(cp["ln"], x), positions,
                               causal=False, kv_x=enc_out,
                               kv_positions=enc_pos, use_rope=False)
        return (x + h,), None
    (x,), _ = _scan(cfg, _maybe_remat(body, cfg), (x,),
                           (params["dec_layers"], params["cross_layers"]))
    return x


def hidden(params, cfg, batch, *, window=0):
    """Final hidden states (after ln_f, frontend tokens trimmed).

    Returns (x (B, S, D), aux_loss).
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = layers.embed(params["embed"], tokens)
    x = constrain(x, "batch", "seq", "embed")
    n_front = 0

    if cfg.family == "vlm":
        patches = layers.linear(params["patch_proj"],
                                batch["patches"].astype(x.dtype))
        x = jnp.concatenate([patches, x], axis=1)
        n_front = patches.shape[1]
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                                 (b, x.shape[1]))

    if cfg.family == "audio":
        enc_out = _encode_audio(params, cfg, batch["frames"])
        x = _decode_audio_full(params, cfg, x, positions, enc_out)
        aux = jnp.zeros((), jnp.float32)
    else:
        x, aux = _backbone(params, cfg, x, positions, window=window)

    x = layers.rmsnorm(params["ln_f"], x)
    if n_front:
        x = x[:, n_front:]
    return x, aux


def forward(params, cfg, batch, *, window=0):
    """Full-sequence forward.  batch keys: tokens (B,S) [+ patches/frames].

    Returns (logits (B,S,V), aux_loss).
    """
    x, aux = hidden(params, cfg, batch, window=window)
    logits = layers.unembed(params["embed"], x) if cfg.tie_embeddings \
        else layers.linear(params["unembed"], x)
    logits = constrain(logits, "batch", "seq", "vocab")
    return logits, aux


def loss_fn(params, cfg, batch, *, window=0):
    x, aux = hidden(params, cfg, batch, window=window)
    if cfg.tie_embeddings:
        # chunked loss: never materialises the (B,S,V) logits
        loss = layers.softmax_xent_chunked(
            params["embed"]["table"], x[:, :-1], batch["tokens"][:, 1:],
            scan_chunks=cfg.scan_chunks)
    else:
        logits = layers.linear(params["unembed"], x)
        loss = layers.softmax_xent(logits[:, :-1], batch["tokens"][:, 1:])
    return loss + aux, (loss, aux)


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------

def init_decode_state(cfg, batch: int, cache_len: int):
    """Decode-state pytree (zeros; dryrun uses eval_shape on this)."""
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        return {"kv": jax.vmap(lambda _: attn_lib.init_kv_cache(
            cfg, batch, cache_len))(jnp.arange(cfg.n_layers))}
    if fam == "ssm":
        n_grp = cfg.n_layers // cfg.slstm_every
        n_ml = cfg.slstm_every - 1
        ml = jnp.zeros((n_grp, n_ml, *ssm_lib.mlstm_state_shape(cfg, batch)),
                       jnp.float32)
        sl = tuple(jnp.broadcast_to(a[None], (n_grp, *a.shape))
                   for a in ssm_lib.slstm_init_state(cfg, batch))
        return {"mlstm": ml, "slstm": sl}
    if fam == "hybrid":
        n_grp = cfg.n_layers // cfg.attn_every
        mb = jnp.zeros((n_grp, cfg.attn_every,
                        *ssm_lib.mamba2_state_shape(cfg, batch)), jnp.float32)
        kv = attn_lib.init_kv_cache(cfg, batch, cache_len)
        kv = {k: jnp.broadcast_to(v[None], (n_grp, *v.shape))
              for k, v in kv.items()}
        return {"mamba": mb, "kv": kv}
    if fam == "audio":
        kv = jax.vmap(lambda _: attn_lib.init_kv_cache(cfg, batch, cache_len))(
            jnp.arange(cfg.n_layers))
        # cross-attention K/V precomputed at prefill; static during decode
        ck = jnp.zeros((cfg.n_layers, batch, cfg.n_frontend_tokens,
                        cfg.n_kv_heads, cfg.head_dim), layers.COMPUTE_DTYPE)
        return {"kv": kv, "cross_k": ck, "cross_v": ck}
    raise ValueError(fam)


def _decode_block(lp, cfg, x, st, pos, window):
    h, st_kv = attn_lib.attention_decode(
        lp["attn"], cfg, layers.rmsnorm(lp["ln1"], x), st, pos,
        window=window)
    x = x + h
    z = layers.rmsnorm(lp["ln2"], x)
    if "moe" in lp:
        y, _ = moe_lib.moe_layer(lp["moe"], cfg, z)
    else:
        y = layers.mlp(lp["mlp"], z, cfg.mlp_type)
    return x + y, st_kv


def decode_step(params, cfg, state, tokens, pos, *, window=0):
    """One decode step.  tokens (B,1) int32; pos (B,) absolute position.

    Returns (logits (B,1,V), new state).
    """
    fam = cfg.family
    x = layers.embed(params["embed"], tokens)

    if fam in ("dense", "vlm", "moe"):
        def body(x, lps):
            lp, st = lps
            x, st = _decode_block(lp, cfg, x, st, pos, window)
            return x, st
        x, kv = _scan(cfg, body, x, (params["layers"], state["kv"]))
        state = {"kv": kv}
    elif fam == "ssm":
        def group(x, lps):
            lp_ml, st_ml, lp_sl, st_sl = lps

            def ml_body(x, a):
                lp, st = a
                y, st = ssm_lib.mlstm_step(lp, cfg, x, st)
                return x + y, st
            x, st_ml = _scan(cfg, ml_body, x, (lp_ml, st_ml))
            y, st_sl = ssm_lib.slstm_step(lp_sl, cfg, x, st_sl)
            return x + y, (st_ml, st_sl)
        x, (ml, sl) = _scan(cfg, 
            group, x, (params["mlstm"], state["mlstm"],
                       params["slstm"], state["slstm"]))
        state = {"mlstm": ml, "slstm": sl}
    elif fam == "hybrid":
        shared = params["shared_attn"]

        def group(x, lps):
            mstack, st_m, st_kv = lps
            x, st_kv = _decode_block(shared, cfg, x, st_kv, pos, window)

            def m_body(x, a):
                lp, st = a
                y, st = ssm_lib.mamba2_step(lp, cfg, x, st)
                return x + y, st
            x, st_m = _scan(cfg, m_body, x, (mstack, st_m))
            return x, (st_m, st_kv)
        x, (mb, kv) = _scan(cfg, 
            group, x, (params["mamba"], state["mamba"], state["kv"]))
        state = {"mamba": mb, "kv": kv}
    elif fam == "audio":
        def body(x, lps):
            lp, cp, st, ck, cv = lps
            x, st = _decode_block(lp, cfg, x, st, pos, window)
            # cross attention against cached encoder K/V
            b = x.shape[0]
            zq = layers.rmsnorm(cp["ln"], x)
            q = layers.linear(cp["attn"]["wq"], zq).reshape(
                b, 1, cfg.n_heads, cfg.head_dim)
            g = cfg.n_heads // cfg.n_kv_heads
            qg = q.transpose(0, 2, 1, 3).reshape(b, cfg.n_kv_heads, g, 1,
                                                 cfg.head_dim)
            kg = ck.transpose(0, 2, 1, 3)
            vg = cv.transpose(0, 2, 1, 3)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                           kg.astype(jnp.float32)) / (cfg.head_dim ** 0.5)
            pr = jax.nn.softmax(s, axis=-1)
            og = jnp.einsum("bhgqk,bhkd->bhgqd", pr, vg.astype(jnp.float32))
            o = og.reshape(b, cfg.n_heads, 1, cfg.head_dim).transpose(
                0, 2, 1, 3).reshape(b, 1, -1).astype(x.dtype)
            x = x + layers.linear(cp["attn"]["wo"], o)
            return x, (st, ck, cv)
        x, (kv, ck, cv) = _scan(cfg, 
            body, x, (params["dec_layers"], params["cross_layers"],
                      state["kv"], state["cross_k"], state["cross_v"]))
        state = {"kv": kv, "cross_k": ck, "cross_v": cv}
    else:
        raise ValueError(fam)

    x = layers.rmsnorm(params["ln_f"], x)
    logits = layers.unembed(params["embed"], x) if cfg.tie_embeddings \
        else layers.linear(params["unembed"], x)
    return logits, state
