"""Model substrate for the assigned architectures."""

from . import attention, layers, model, moe, sharding, ssm

__all__ = ["attention", "layers", "model", "moe", "sharding", "ssm"]
