"""Attention substrate: GQA + RoPE + sliding window, three execution paths.

* ``xla_chunked`` (default): flash-style online-softmax double scan over
  query/key chunks in pure JAX — O(chunk^2) transient memory, identical
  math to the Pallas kernel (kernels/flash_attention.py), runs on any
  backend.  ``causal_skip=True`` switches the outer loop to a Python
  unroll with *static* per-q-chunk kv extents, halving attention FLOPs
  for causal masks (a §Perf optimization — see EXPERIMENTS.md).
* ``xla_full``: naive einsum attention (testing / tiny shapes).
* ``pallas``: the Pallas kernel, for real TPU runs.

Layouts: activations (B, S, D); internally (B, Hkv, G, S, Dh) so grouped
queries never materialise repeated K/V (important for MQA kv=1 archs).
Decode keeps a (B, S_cache, Hkv, Dh) cache (ring-buffer for sliding
window) and writes the new token at a traced position.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import layers
from .sharding import constrain
from ..kernels import ops as kernel_ops

NEG_INF = -1e30


def init_attention(key, cfg, cross: bool = False):
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.init_linear(ks[0], d, hq * dh, bias=cfg.qkv_bias),
        "wk": layers.init_linear(ks[1], d, hkv * dh, bias=cfg.qkv_bias),
        "wv": layers.init_linear(ks[2], d, hkv * dh, bias=cfg.qkv_bias),
        "wo": layers.init_linear(ks[3], hq * dh, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.init_rmsnorm(dh)
        p["k_norm"] = layers.init_rmsnorm(dh)
    return p


def _project_qkv(p, cfg, x, kv_x=None):
    """-> q (B,Sq,Hq,Dh), k/v (B,Sk,Hkv,Dh)."""
    b, sq, _ = x.shape
    kv_x = x if kv_x is None else kv_x
    sk = kv_x.shape[1]
    q = layers.linear(p["wq"], x).reshape(b, sq, cfg.n_heads, cfg.head_dim)
    k = layers.linear(p["wk"], kv_x).reshape(b, sk, cfg.n_kv_heads, cfg.head_dim)
    v = layers.linear(p["wv"], kv_x).reshape(b, sk, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = layers.rmsnorm(p["q_norm"], q)
        k = layers.rmsnorm(p["k_norm"], k)
    return q, k, v


def _grouped(q, k, v, hkv):
    """(B,S,H,D) -> q (B,Hkv,G,Sq,D), k/v (B,Hkv,Sk,D)."""
    b, sq, hq, d = q.shape
    g = hq // hkv
    q = q.transpose(0, 2, 1, 3).reshape(b, hkv, g, sq, d)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    return q, k, v


def _chunk_attn_block(q, k, v, qpos0, kpos0, *, causal, window, scale):
    """One (q-chunk x kv-chunk) flash block. q (B,Hkv,G,cq,D), k/v (B,Hkv,ck,D).

    Returns (scores_exp, m, l, pv) pieces via the caller-held running state.
    """
    s = jnp.einsum("bhgqd,bhkd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    cq, ck = q.shape[3], k.shape[2]
    qpos = qpos0 + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 0)
    kpos = kpos0 + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 1)
    mask = jnp.ones((cq, ck), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    return jnp.where(mask, s, NEG_INF)


def _pick_chunk(s: int, chunk: int) -> int:
    """Largest divisor of s that is <= chunk (VLM prepends patch tokens, so
    sequence lengths are not always powers of two)."""
    for c in range(min(chunk, s), 0, -1):
        if s % c == 0:
            return c
    return s


def _flash_xla(q, k, v, *, causal: bool, window: int, chunk: int,
               q_offset: int = 0, causal_skip: bool = False,
               scan_chunks: bool = True):
    """Flash-style attention, pure JAX.  q (B,Hkv,G,Sq,D), k/v (B,Hkv,Sk,D)."""
    b, hkv, g, sq, d = q.shape
    sk = k.shape[2]
    scale = 1.0 / (d ** 0.5)
    cq = _pick_chunk(sq, chunk)
    ck = _pick_chunk(sk, chunk)
    nq, nk = sq // cq, sk // ck

    def q_chunk_body(qi, qc, nk_eff):
        """Online softmax over kv chunks for one q chunk. qc (B,Hkv,G,cq,D)."""
        m0 = jnp.full((b, hkv, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, cq, d), jnp.float32)

        # rematerialised backward: without the checkpoints, scan VJP stacks
        # every block's probabilities — the full S x S matrix, which is
        # exactly what flash attention exists to avoid
        @jax.checkpoint
        def kv_body(carry, ki):
            m, l, acc = carry
            kc = jax.lax.dynamic_slice_in_dim(k, ki * ck, ck, axis=2)
            vc = jax.lax.dynamic_slice_in_dim(v, ki * ck, ck, axis=2)
            s = _chunk_attn_block(qc, kc, vc, q_offset + qi * cq, ki * ck,
                                  causal=causal, window=window, scale=scale)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vc.astype(jnp.float32))
            return (m_new, l, acc), None

        if scan_chunks:
            (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0),
                                          jnp.arange(nk_eff))
        else:
            carry = (m0, l0, a0)
            for ki in range(nk_eff):
                carry, _ = kv_body(carry, jnp.int32(ki))
            m, l, acc = carry
        safe = jnp.where(l == 0.0, 1.0, l)
        return (acc / safe[..., None]).astype(q.dtype)

    skip_ok = causal_skip and causal and q_offset == 0 and window == 0
    if skip_ok or not scan_chunks:
        # Python outer loop over q chunks.  With causal_skip the per-chunk
        # kv extent is STATIC — only the lower triangle of kv blocks is
        # ever computed (~2x fewer attention FLOPs, a §Perf optimization).
        # With scan_chunks=False (cost measurement) the extent stays FULL
        # so flops match the scanned baseline exactly.
        outs = []
        for qi in range(nq):
            qc = jax.lax.slice_in_dim(q, qi * cq, (qi + 1) * cq, axis=3)
            nk_eff = (qi * cq + cq + ck - 1) // ck if skip_ok else nk
            outs.append(q_chunk_body(qi, qc, nk_eff))
        out = jnp.concatenate(outs, axis=3)
    else:
        qr = q.reshape(b, hkv, g, nq, cq, d).transpose(3, 0, 1, 2, 4, 5)

        @jax.checkpoint
        def outer(_, qi_qc):
            qi, qc = qi_qc
            return None, q_chunk_body(qi, qc, nk)

        _, out = jax.lax.scan(outer, None, (jnp.arange(nq), qr))
        out = out.transpose(1, 2, 3, 0, 4, 5).reshape(b, hkv, g, sq, d)
    return out


def attention(p, cfg, x, positions, *, causal=True, window=0, kv_x=None,
              kv_positions=None, use_rope=True):
    """Full-sequence attention (train / prefill / encoder / cross).

    Args:
      x: (B, Sq, D) queries' activations.
      positions: (B, Sq) int positions (for RoPE + causal mask offset).
      kv_x: optional (B, Sk, D) for cross-attention.

    Returns: (B, Sq, D).
    """
    b, sq, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, kv_x)
    if use_rope:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        kp = positions if kv_positions is None else kv_positions
        k = layers.apply_rope(k, kp, cfg.rope_theta)

    if cfg.attn_impl == "pallas":
        qh = q.transpose(0, 2, 1, 3)
        kh = k.transpose(0, 2, 1, 3)
        vh = v.transpose(0, 2, 1, 3)
        out = kernel_ops.flash_attention(qh, kh, vh, causal=causal,
                                         window=window)
        out = out.transpose(0, 2, 1, 3)
    elif cfg.attn_impl == "xla_full" or sq * k.shape[1] <= 512 * 512:
        qg, kg, vg = _grouped(q, k, v, cfg.n_kv_heads)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                       kg.astype(jnp.float32)) / (cfg.head_dim ** 0.5)
        qpos = positions[:, None, None, :, None]
        kpos = (kv_positions if kv_positions is not None
                else positions)[:, None, None, None, :]
        mask = jnp.ones_like(s, dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        og = jnp.einsum("bhgqk,bhkd->bhgqd", pr, vg.astype(jnp.float32))
        out = og.reshape(b, cfg.n_heads, sq, cfg.head_dim).transpose(0, 2, 1, 3)
        out = out.astype(x.dtype)
    else:
        qg, kg, vg = _grouped(q, k, v, cfg.n_kv_heads)
        og = _flash_xla(qg, kg, vg, causal=causal, window=window,
                        chunk=cfg.attn_chunk, causal_skip=cfg.causal_skip,
                        scan_chunks=cfg.scan_chunks)
        out = og.reshape(b, cfg.n_heads, sq, cfg.head_dim).transpose(0, 2, 1, 3)

    out = constrain(out, "batch", "seq", "heads", None)
    out = out.reshape(b, sq, cfg.n_heads * cfg.head_dim)
    return layers.linear(p["wo"], out)


def init_kv_cache(cfg, batch: int, length: int, dtype=layers.COMPUTE_DTYPE):
    shape = (batch, length, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention_decode(p, cfg, x, cache, pos, *, window=0, use_rope=True):
    """Single-token decode against a KV cache.

    Args:
      x: (B, 1, D) current-token activations.
      cache: {'k','v'}: (B, L, Hkv, Dh).  For sliding-window serving, L is
        the window (ring buffer); otherwise L = max seq.
      pos: (B,) int32 absolute position of the new token.

    Returns: (out (B,1,D), new_cache).
    """
    b = x.shape[0]
    L = cache["k"].shape[1]
    q, k, v = _project_qkv(p, cfg, x)
    if use_rope:
        q = layers.apply_rope(q, pos[:, None], cfg.rope_theta)
        k = layers.apply_rope(k, pos[:, None], cfg.rope_theta)

    slot = (pos % L) if window > 0 else jnp.minimum(pos, L - 1)

    def upd(c, new):
        return jax.vmap(
            lambda cb, nb, s: jax.lax.dynamic_update_slice_in_dim(cb, nb, s, 0)
        )(c, new.astype(c.dtype), slot)

    cache = {"k": upd(cache["k"], k), "v": upd(cache["v"], v)}

    kg = cache["k"].transpose(0, 2, 1, 3)           # (B,Hkv,L,D)
    vg = cache["v"].transpose(0, 2, 1, 3)
    g = cfg.n_heads // cfg.n_kv_heads
    qg = q.transpose(0, 2, 1, 3).reshape(b, cfg.n_kv_heads, g, 1,
                                         cfg.head_dim)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                   kg.astype(jnp.float32)) / (cfg.head_dim ** 0.5)
    # valid cache entries: absolute positions <= pos and (window) in range
    idx = jnp.arange(L)[None, :]                     # slots
    if window > 0:
        # ring buffer: every slot holds one of the last L tokens
        valid = idx < jnp.minimum(pos[:, None] + 1, L)
    else:
        valid = idx <= pos[:, None]
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    og = jnp.einsum("bhgqk,bhkd->bhgqd", pr, vg.astype(jnp.float32))
    out = og.reshape(b, cfg.n_heads, 1, cfg.head_dim).transpose(0, 2, 1, 3)
    out = out.reshape(b, 1, cfg.n_heads * cfg.head_dim).astype(x.dtype)
    return layers.linear(p["wo"], out), cache
