"""Logical-axis sharding constraints for model activations.

Models annotate activations with *logical* axes ('batch', 'seq', 'embed',
'heads', 'ff', 'vocab', 'experts', ...).  The launcher installs a mapping
from logical axes to mesh axes for the current mesh (single-pod vs
multi-pod differ only in the 'batch' mapping); on CPU/test runs with no
mapping installed, constraints are no-ops, so the same model code runs
everywhere.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()

# Default logical->mesh mapping used by the production launcher.
SINGLE_POD_RULES = {
    "batch": ("data",),
    "seq": None,
    "embed": None,
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "expert_cap": None,
    "state": None,
}

MULTI_POD_RULES = dict(SINGLE_POD_RULES, batch=("pod", "data"))


def rules_for_mesh(mesh, seq_shard: bool = False) -> dict:
    """seq_shard=True turns on Megatron-style sequence parallelism: the
    residual stream (and everything constrained on 'seq') is sharded over
    the tensor-parallel axis between blocks, dividing saved remat
    activations by the model-axis size at the cost of gather/scatter
    collectives around attention/MLP."""
    rules = MULTI_POD_RULES if "pod" in mesh.axis_names else SINGLE_POD_RULES
    if seq_shard:
        rules = dict(rules, seq=("model",))
    return rules


@contextlib.contextmanager
def logical_rules(rules: dict | None):
    """Install a logical->mesh mapping for the enclosed trace."""
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def spec(*logical_axes) -> P:
    """PartitionSpec for the given logical axes under the current rules."""
    rules = getattr(_state, "rules", None)
    if rules is None:
        return P()
    out = []
    used: set = set()
    for ax in logical_axes:
        m = rules.get(ax) if ax is not None else None
        if m is None or any(a in used for a in m):
            # a mesh axis may appear once per spec — later logical axes
            # that would reuse one (e.g. vocab when seq already holds
            # 'model' under sequence parallelism) fall back to replicated
            out.append(None)
            continue
        used.update(m)
        out.append(m[0] if len(m) == 1 else tuple(m))
    return P(*out)


def constrain(x: jax.Array, *logical_axes) -> jax.Array:
    """with_sharding_constraint against the current logical rules (no-op
    when no rules are installed, e.g. CPU unit tests)."""
    rules = getattr(_state, "rules", None)
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec(*logical_axes))
