"""Mixture-of-Experts layer: shared + routed experts, two dispatch modes.

* ``onehot`` (baseline): Switch/Mesh-TF-style capacity dispatch.  The
  position-within-expert comes from a one-hot cumsum; tokens are placed
  into an (E, C, D) buffer by scatter.  Simple, fully static, and the
  historical standard — but the cumsum is O(T*E) bytes.
* ``sort`` (optimized, §Perf): argsort tokens by expert id; the
  position-within-expert falls out of the sorted order, O(T log T) with
  no O(T*E) intermediate.  Same (E, C, D) buffer and expert einsum.

Experts are sharded over the 'experts' logical axis (mesh 'model' axis →
expert parallelism); the scatter/gather across that axis is the all-to-all
of classic expert-parallel MoE, inserted by SPMD partitioning.

Both modes drop tokens beyond capacity C = ceil(T * top_k / E * cf)
(capacity_factor cf, default 1.25), the standard trade; the router
load-balance aux loss (Switch-style) keeps drops rare.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .sharding import constrain


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def init_moe(key, cfg):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    scale = d ** -0.5
    p = {
        "router": {"w": jax.random.normal(ks[0], (d, e), jnp.float32) * scale},
        "wi": jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale,
        "wg": jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale,
        "wo": jax.random.normal(ks[3], (e, f, d), jnp.float32) * (f ** -0.5),
    }
    if cfg.n_shared_experts:
        p["shared"] = layers.init_mlp(
            ks[4], d, cfg.n_shared_experts * cfg.d_ff_expert, "swiglu")
    return p


def _expert_ffn(p, xb):
    """xb (E, C, D) -> (E, C, D); swiglu experts."""
    dt = xb.dtype
    h = jnp.einsum("ecd,edf->ecf", xb, p["wi"].astype(dt))
    g = jnp.einsum("ecd,edf->ecf", xb, p["wg"].astype(dt))
    h = jax.nn.silu(h) * g
    h = constrain(h, "experts", "expert_cap", None)
    return jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt))


def _dispatch_group(cfg, xt, tope, topw, cap):
    """Capacity dispatch + expert gather for ONE group.

    xt (T, D); tope/topw (T, k).  Returns (buf (E,C,D), flat_e, posc, keepw)
    where keepw is the combine weight (0 for dropped tokens).
    """
    t, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k
    flat_e = tope.reshape(t * k)
    flat_w = topw.reshape(t * k)
    tok_of = jnp.repeat(jnp.arange(t), k)

    if cfg.moe_dispatch == "sort":
        # position-within-expert via stable sort by expert id (§Perf:
        # O(Tk log Tk), no O(Tk*E) one-hot cumsum intermediate)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        counts = jnp.bincount(flat_e, length=e)
        starts = jnp.cumsum(counts) - counts
        pos_sorted = jnp.arange(t * k) - starts[sorted_e]
        pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)
    else:
        # baseline: one-hot cumsum (Switch/Mesh-TF style)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) - 1)
        pos = jnp.take_along_axis(pos, flat_e[:, None], 1)[:, 0]

    keep = pos < cap
    posc = jnp.where(keep, pos, 0)
    buf = jnp.zeros((e, cap, d), xt.dtype)
    buf = buf.at[flat_e, posc].set(
        jnp.where(keep[:, None], xt[tok_of], 0), mode="drop")
    keepw = jnp.where(keep, flat_w, 0.0)
    return buf, flat_e, posc, keepw


def moe_layer(p, cfg, x):
    """x (B, S, D) -> (out (B, S, D), aux_loss scalar).

    Tokens are dispatched within ``moe_groups`` groups (the launcher sets
    moe_groups = data-axis size): capacity is per-group, the (G, E, C, D)
    buffer shards as P('data', 'model', None, None), and no tensor ever
    scales with the GLOBAL token count x expert count.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    g = cfg.moe_groups
    if t % g or t // g < 1:
        g = 1
    tg = t // g
    xt = x.reshape(g, tg, d)
    xt = constrain(xt, "batch", None, None)

    logits = (xt.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (G, Tg, E)
    topw, tope = jax.lax.top_k(probs, k)                        # (G, Tg, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    # Switch-style load-balance loss (global).
    density = jnp.mean(jax.nn.one_hot(tope[..., 0], e), axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = cfg.router_aux_weight * e * jnp.sum(density * mean_prob)

    cap = _round_up(max(1, int(tg * k / e * cfg.capacity_factor)), 8)

    buf, flat_e, posc, keepw = jax.vmap(
        lambda xg, eg, wg: _dispatch_group(cfg, xg, eg, wg, cap)
    )(xt, tope, topw)
    buf = constrain(buf, "batch", "experts", "expert_cap", None)

    yb = jax.vmap(lambda bg: _expert_ffn(p, bg))(buf)           # (G,E,C,D)
    yb = constrain(yb, "batch", "experts", "expert_cap", None)

    def combine(ybg, eg, pg, wg):
        y_tok = ybg[eg, pg] * wg[:, None].astype(ybg.dtype)     # (Tg*k, D)
        return jnp.sum(y_tok.reshape(tg, k, d), axis=1)

    out = jax.vmap(combine)(yb, flat_e, posc, keepw)            # (G, Tg, D)

    if cfg.n_shared_experts:
        out = out + layers.mlp(p["shared"], xt, "swiglu")

    return out.reshape(b, s, d), aux
