"""Training observability: per-round telemetry for the GBDT trainers.

``TrainReport`` is the struct-of-arrays of per-round scalars that the
scanned trainers emit when ``GBDTConfig.telemetry`` is on; see
:mod:`repro.obs.report` for the field reference and the JSON schema.
"""

from .predict import PredictReport
from .report import (TrainReport, collective_bytes_per_round,
                     mean_train_loss, round_report)

__all__ = [
    "PredictReport",
    "TrainReport",
    "collective_bytes_per_round",
    "mean_train_loss",
    "round_report",
]
