"""Inference observability: latency/throughput telemetry for serving.

``PredictReport`` is the host-side record a serving loop
(:mod:`repro.launch.serve_gbdt`) or the predict benchmark
(``benchmarks/bench_predict.py``) emits: per-request wall-clock
latencies plus the workload shape, summarized into throughput and tail
percentiles.  Follows the :mod:`repro.obs.report` JSON-schema
convention (``repro.obs.PredictReport/v1``); consumed by
``repro.launch.report --section predict``.
"""

from __future__ import annotations

import json
from typing import NamedTuple

import numpy as np

SCHEMA = "repro.obs.PredictReport/v1"


class PredictReport(NamedTuple):
    """Latency record of one serving/benchmark run.

    Attributes:
      latencies_s: per-request (per-microbatch) wall-clock seconds,
        warm — warmup/compile requests excluded.
      rows_per_request: rows served per request (microbatch size).
      engine: workload description — free-form but conventionally
        n_trees / max_depth / tree_chunk / backend / binned / n_features.
      baseline_rows_per_s: optional reference throughput (the per-tree
        scan) for the speedup field; 0 disables it.
    """
    latencies_s: np.ndarray
    rows_per_request: int
    engine: dict
    baseline_rows_per_s: float = 0.0

    @property
    def n_requests(self) -> int:
        return int(np.asarray(self.latencies_s).shape[0])

    def summarize(self) -> dict:
        """Scalar summary (everything JSON-serialisable): throughput is
        total rows over total wall-clock; percentiles are per-request."""
        lat = np.asarray(self.latencies_s, np.float64)
        if lat.size == 0:
            raise ValueError("PredictReport needs at least one request")
        total_s = float(lat.sum())
        rows = float(self.rows_per_request) * lat.size
        rows_per_s = rows / total_s if total_s > 0 else float("inf")
        out = {
            "n_requests": self.n_requests,
            "rows_per_request": int(self.rows_per_request),
            "rows_per_s": rows_per_s,
            "latency_ms": {
                "p50": float(np.percentile(lat, 50) * 1e3),
                "p99": float(np.percentile(lat, 99) * 1e3),
                "mean": float(lat.mean() * 1e3),
                "max": float(lat.max() * 1e3),
            },
        }
        if self.baseline_rows_per_s > 0:
            out["baseline_rows_per_s"] = float(self.baseline_rows_per_s)
            out["speedup_vs_scan"] = rows_per_s / self.baseline_rows_per_s
        return out

    def to_json(self, path: str | None = None, *, indent: int = 1) -> str:
        """Serialise (schema + engine + summary + raw latencies);
        optionally write to ``path``."""
        rec = {"schema": SCHEMA,
               "engine": dict(self.engine),
               "summary": self.summarize(),
               "latencies_s": [float(v) for v in
                               np.asarray(self.latencies_s, np.float64)]}
        s = json.dumps(rec, indent=indent)
        if path is not None:
            with open(path, "w") as fh:
                fh.write(s)
        return s
