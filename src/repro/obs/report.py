"""Per-round training telemetry: the :class:`TrainReport` struct-of-arrays.

The scanned trainers (``boosting.fit``, ``distributed.fit_distributed``)
emit one :class:`TrainReport` row per boosting round as *additional*
``lax.scan`` outputs of the round step, behind ``GBDTConfig.telemetry``.
Because the report rides the existing scan it costs nothing when off
(the flag is a static jit argument — the telemetry-off program is the
exact pre-telemetry graph) and preserves the O(1)-compile property when
on (still one round-step trace regardless of ``n_trees``).

Every field is derived from intermediates the trainer already computes
(grad/hess panel, the psum'd split-gain panel), so enabling telemetry
cannot change the numerics of the fitted forest — the equivalence tests
in tests/test_scan_trainer.py pin that.

Fields (all shape ``(n_trees,)``, one entry per round):

  train_loss        mean train loss after the round's margin update
                    (logistic: mean log-loss; mse: mean 0.5*(m-y)^2)
  grad_norm         L2 norm of the gradient vector at round start
  hess_norm         L2 norm of the hessian vector at round start
  n_splits          realized (gain > 0) splits in the round's tree
  best_gain_max     largest realized split gain in the tree (0 if none)
  best_gain_mean    mean realized split gain (0 if no splits)
  all_gather_bytes  estimated all_gather payload per worker for the
                    round's candidate proposal (0 on a single host)
  psum_bytes        estimated psum payload per worker for the round's
                    histogram / leaf reductions (0 on a single host)
  hist_updates      MEASURED histogram scatter updates issued for the
                    round's tree (rows scattered x features, summed
                    over levels; cluster-wide in the distributed
                    trainer).  Direct growth pays n*f per level;
                    subtraction growth only the LEFT-routed rows —
                    this field is how the ~2x reduction is audited.

The distributed byte fields are *estimates* computed host-side from
static shapes (:func:`collective_bytes_per_round`) in the spirit of
Huang & Yi's communication-cost accounting — they count the logical
collective payload, not wire-level implementation detail.  With
``GBDTConfig.subtract`` on, only the half-width left-child panels enter
the per-level histogram psum, and the estimator accounts for it.
"""

from __future__ import annotations

import json
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class TrainReport(NamedTuple):
    """Struct-of-arrays of per-round training scalars (see module doc)."""
    train_loss: jax.Array
    grad_norm: jax.Array
    hess_norm: jax.Array
    n_splits: jax.Array
    best_gain_max: jax.Array
    best_gain_mean: jax.Array
    all_gather_bytes: jax.Array
    psum_bytes: jax.Array
    hist_updates: jax.Array

    @property
    def n_rounds(self) -> int:
        return int(self.train_loss.shape[0])

    def to_dict(self) -> dict:
        """Full per-round record as JSON-ready lists."""
        out = {}
        for name, arr in self._asdict().items():
            a = np.asarray(arr)
            out[name] = [int(v) for v in a] if np.issubdtype(
                a.dtype, np.integer) else [float(v) for v in a]
        return out

    def summarize(self) -> dict:
        """Host-side scalar summary (everything JSON-serialisable)."""
        loss = np.asarray(self.train_loss, np.float64)
        gnorm = np.asarray(self.grad_norm, np.float64)
        splits = np.asarray(self.n_splits)
        gmax = np.asarray(self.best_gain_max, np.float64)
        ag = np.asarray(self.all_gather_bytes, np.float64)
        ps = np.asarray(self.psum_bytes, np.float64)
        upd = np.asarray(self.hist_updates, np.float64)
        return {
            "n_rounds": self.n_rounds,
            "train_loss": {"first": float(loss[0]), "final": float(loss[-1]),
                           "min": float(loss.min())},
            "grad_norm": {"first": float(gnorm[0]), "final": float(gnorm[-1])},
            "splits": {"total": int(splits.sum()),
                       "mean_per_tree": float(splits.mean()),
                       "min": int(splits.min()), "max": int(splits.max())},
            "best_gain": {"max": float(gmax.max()),
                          "final": float(gmax[-1])},
            "collective_bytes": {"all_gather_total": float(ag.sum()),
                                 "psum_total": float(ps.sum()),
                                 "per_round": float((ag + ps).mean())},
            "scatter_updates": {"total": float(upd.sum()),
                                "per_round_mean": float(upd.mean())},
        }

    def to_json(self, path: str | None = None, *, indent: int = 1) -> str:
        """Serialise the full report (+ summary) to JSON; optionally write
        it to ``path``.  Schema is pinned by tests/test_telemetry.py."""
        rec = {"schema": "repro.obs.TrainReport/v2",
               "n_rounds": self.n_rounds,
               "rounds": self.to_dict(),
               "summary": self.summarize()}
        s = json.dumps(rec, indent=indent)
        if path is not None:
            with open(path, "w") as fh:
                fh.write(s)
        return s


def mean_train_loss(margin, y, objective: str, *, weight=None,
                    n_global: int | None = None, psum=None):
    """Mean train loss of ``margin`` vs ``y`` (traceable).

    ``weight`` masks rows out of the mean (distributed padding);
    ``n_global`` is the true global row count and ``psum`` the cross-
    worker reduction — both default to the single-host interpretation.
    """
    if objective == "logistic":
        per_row = jax.nn.softplus(margin) - y * margin
    elif objective == "mse":
        per_row = 0.5 * (margin - y) ** 2
    else:
        raise ValueError(f"unknown objective {objective!r}")
    if weight is not None:
        per_row = per_row * weight
    total = jnp.sum(per_row)
    if psum is not None:
        total = psum(total)
    n = margin.shape[0] if n_global is None else n_global
    return total / n


def round_report(*, margin, y, g, h, objective: str, stats,
                 n_global: int | None = None, weight=None,
                 psum=None) -> TrainReport:
    """Build one round's TrainReport row (all 0-d arrays, scan-stackable).

    Args:
      margin: post-update margin (the round's loss is measured after its
        tree is applied).
      g, h: the grad/hess panel the round's tree was built from (already
        masked by ``weight`` in the distributed trainer).
      stats: :class:`repro.core.tree.TreeStats` from ``build_tree``.
      n_global / weight / psum: distributed plumbing, as in
        :func:`mean_train_loss`.

    The collective-byte fields are zero here; the distributed driver
    fills them host-side from :func:`collective_bytes_per_round`.
    """
    sq_g = jnp.sum(g * g)
    sq_h = jnp.sum(h * h)
    upd = stats.hist_updates
    if psum is not None:
        sq_g, sq_h = psum(sq_g), psum(sq_h)
        upd = psum(upd)               # cluster-wide scatter-update count
    loss = mean_train_loss(margin, y, objective, weight=weight,
                           n_global=n_global, psum=psum)
    mean_gain = stats.gain_sum / jnp.maximum(
        stats.n_splits.astype(jnp.float32), 1.0)
    zero = jnp.float32(0.0)
    return TrainReport(
        train_loss=loss.astype(jnp.float32),
        grad_norm=jnp.sqrt(sq_g).astype(jnp.float32),
        hess_norm=jnp.sqrt(sq_h).astype(jnp.float32),
        n_splits=stats.n_splits.astype(jnp.int32),
        best_gain_max=stats.gain_max.astype(jnp.float32),
        best_gain_mean=mean_gain.astype(jnp.float32),
        all_gather_bytes=zero,
        psum_bytes=zero,
        hist_updates=upd.astype(jnp.float32),
    )


def collective_bytes_per_round(cfg, n_features: int, n_workers: int,
                               *, dtype_bytes: int = 4):
    """Estimated per-worker collective payload, one entry per round.

    Counts the logical payload each worker *receives* per round of
    ``distributed.fit_distributed``:

      all_gather — the candidate-proposal gather (Algorithm 1's
        AllReduce-combine step): ``W * f * k`` floats for the
        pool-resample ('random') and quantile-merge strategies; zero for
        'uniform_range' (its pmin/pmax ride the psum column).
      psum — the per-level histogram AllReduce
        (``max_depth * frontier * f * nbins * 2`` floats, with
        ``frontier`` replaced by the half-width parent panel
        ``max(frontier // 2, 1)`` under ``cfg.subtract`` — only the
        left-child panels cross the mesh), the leaf grad/hess segment
        reduction (``2^max_depth * 2``), the uniform_range pmin/pmax
        (``2 * f``) when applicable, and the telemetry scalar
        reductions (4 floats) when telemetry is on.

    With ``repropose_each_round=False`` the proposal collectives only
    happen in round 0; later rounds reuse the round-0 candidate grid.

    Returns:
      ``(all_gather_bytes, psum_bytes)`` — two ``(n_trees,)`` float32
      numpy arrays, ready to splice into a :class:`TrainReport`.
    """
    k = cfg.n_candidates
    nbins = cfg.nbins
    frontier = 2 ** max(cfg.max_depth - 1, 0)

    if cfg.strategy in ("random", "weighted_quantile", "gk_quantile"):
        ag_prop = n_workers * n_features * k * dtype_bytes
        ps_prop = 0
    elif cfg.strategy == "uniform_range":
        ag_prop = 0
        ps_prop = 2 * n_features * dtype_bytes          # pmin + pmax
    else:
        ag_prop, ps_prop = 0, 0

    hist_nodes = (max(frontier // 2, 1) if getattr(cfg, "subtract", False)
                  else frontier)
    ps_tree = (cfg.max_depth * hist_nodes * n_features * nbins * 2
               + 2 ** cfg.max_depth * 2) * dtype_bytes
    ps_telemetry = 4 * dtype_bytes if getattr(cfg, "telemetry", False) else 0

    ag = np.zeros(cfg.n_trees, np.float32)
    ps = np.full(cfg.n_trees, ps_tree + ps_telemetry, np.float32)
    prop_rounds = slice(None) if cfg.repropose_each_round else slice(0, 1)
    ag[prop_rounds] += ag_prop
    ps[prop_rounds] += ps_prop
    return ag, ps
