"""Stable top-level API.

Everything a user of the GBDT library needs lives here; the module
layout underneath (``repro.core.*``, ``repro.kernels.*``) is an
implementation detail and may move between releases.  Examples and
downstream code should import from ``repro`` directly::

    import repro

    model = repro.fit(x, y, repro.GBDTConfig(strategy="random"))
    labels = model.predict(x)                     # output="label"
    repro.save_gbdt("model.npz", model)           # serving checkpoint
    margins = repro.load_gbdt("model.npz").predict(x, output="margin")
"""

from .checkpoint import load_gbdt, save_gbdt
from .core.boosting import (GBDTConfig, GBDTModel, accuracy, fit,
                            fit_reference, mape)
from .core.distributed import fit_distributed
from .core.predict import forest_predict, traverse_trace_count
from .core.tree import Forest, Tree
from .kernels.ops import HistSpec, TraverseSpec
from .obs import PredictReport, TrainReport

__all__ = [
    "Forest",
    "GBDTConfig",
    "GBDTModel",
    "HistSpec",
    "PredictReport",
    "TrainReport",
    "TraverseSpec",
    "Tree",
    "accuracy",
    "fit",
    "fit_distributed",
    "fit_reference",
    "forest_predict",
    "load_gbdt",
    "mape",
    "save_gbdt",
    "traverse_trace_count",
]
