"""Stable top-level API.

Everything a user of the GBDT library needs lives here; the module
layout underneath (``repro.core.*``, ``repro.kernels.*``) is an
implementation detail and may move between releases.  Examples and
downstream code should import from ``repro`` directly::

    import repro

    model = repro.fit(x, y, repro.GBDTConfig(strategy="random"))
    labels = model.predict(x)                     # output="label"
"""

from .core.boosting import (GBDTConfig, GBDTModel, accuracy, fit,
                            fit_reference, mape)
from .core.distributed import fit_distributed
from .core.tree import Forest, Tree
from .kernels.ops import HistSpec
from .obs import TrainReport

__all__ = [
    "Forest",
    "GBDTConfig",
    "GBDTModel",
    "HistSpec",
    "TrainReport",
    "Tree",
    "accuracy",
    "fit",
    "fit_distributed",
    "fit_reference",
    "mape",
]
