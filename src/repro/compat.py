"""Cross-version JAX compatibility shims.

The library targets the newest public APIs (``jax.shard_map``,
``jax.set_mesh``) but must also run on the 0.4.x line installed in the
benchmark container, where the same functionality lives under
``jax.experimental.shard_map`` (with ``check_rep`` instead of
``check_vma``) and the global-mesh context is ``Mesh.__enter__``.

Call sites import from here only:

    from repro import compat
    compat.shard_map(fn, mesh=mesh, in_specs=..., out_specs=...)
    with compat.use_mesh(mesh): ...
"""

from __future__ import annotations

import contextlib

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` with graceful fallback to the experimental API.

    ``check_vma`` maps onto the older ``check_rep`` flag — both toggle
    the replication/varying-manual-axes checker, which rejects some
    valid collective programs on older releases, so we default it off.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


@contextlib.contextmanager
def use_mesh(mesh):
    """Install ``mesh`` as the ambient mesh for the enclosed trace.

    Newest JAX: ``jax.set_mesh`` context manager.  Mid vintages:
    ``jax.sharding.use_mesh``.  0.4.x: the legacy ``with mesh:`` global
    mesh context (sufficient for jit-with-NamedSharding lowering, which
    is all the launcher needs).
    """
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield
    elif hasattr(jax.sharding, "use_mesh"):
        with jax.sharding.use_mesh(mesh):
            yield
    else:
        with mesh:
            yield
