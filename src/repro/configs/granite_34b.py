"""granite-34b — llama-arch code model, MQA (kv=1).

[arXiv:2405.04324]  88L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152.
d_ff = 4*d_model with a plain GELU MLP (granite code family).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24_576, vocab_size=49_152,
    mlp_type="gelu", rope_theta=1e4, seq_shard=True, train_microbatches=4,
)

SMOKE = ArchConfig(
    name="granite-34b-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=1,
    d_ff=1024, vocab_size=512,
    mlp_type="gelu",
)
