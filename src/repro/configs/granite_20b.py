"""granite-20b — llama-arch code model, MQA (kv=1).

[arXiv:2405.04324]  52L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24_576, vocab_size=49_152,
    mlp_type="gelu", rope_theta=1e4, seq_shard=True, train_microbatches=4,
)

SMOKE = ArchConfig(
    name="granite-20b-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=1,
    d_ff=1024, vocab_size=512,
    mlp_type="gelu",
)
