"""internvl2-1b — InternViT + Qwen2-0.5B-style language decoder.

[arXiv:2404.16821]  24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
The vision tower (InternViT) is a STUB per the brief: input_specs()
provides 256 precomputed patch embeddings per image, prepended to the
text tokens.  QKV bias per Qwen2.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab_size=151_655,
    qkv_bias=True, mlp_type="swiglu", rope_theta=1e6,
    frontend="vision_stub", n_frontend_tokens=256,
)

SMOKE = ArchConfig(
    name="internvl2-1b-smoke", family="vlm",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab_size=512,
    qkv_bias=True, mlp_type="swiglu",
    frontend="vision_stub", n_frontend_tokens=16,
)
