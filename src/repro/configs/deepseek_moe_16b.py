"""deepseek-moe-16b — fine-grained MoE, 2 shared + 64 routed top-6.

[arXiv:2401.06066]  28L d_model=2048 16H (GQA kv=16) d_ff=1408(expert)
vocab=102400.  Simplification vs the release: every layer is MoE (the HF
model keeps layer 0 dense); noted in DESIGN.md.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=102_400,
    n_experts=64, n_shared_experts=2, top_k=6, d_ff_expert=1408,
    mlp_type="swiglu", rope_theta=1e4, seq_shard=True, train_microbatches=2,
)

SMOKE = ArchConfig(
    name="deepseek-moe-16b-smoke", family="moe",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=96, vocab_size=512,
    n_experts=4, n_shared_experts=1, top_k=2, d_ff_expert=96,
    mlp_type="swiglu",
)
