"""Architecture config schema shared by all assigned architectures.

Every ``configs/<id>.py`` exports ``CONFIG`` (the exact assigned numbers)
and ``SMOKE`` (a reduced same-family variant: <=2 layers, d_model<=512,
<=4 experts) used by the CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    # --- attention ---
    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    sliding_window: int = 0          # 0 = full attention
    # sliding window used automatically for the long_500k shape on
    # otherwise-quadratic archs (see DESIGN.md §Arch-applicability)
    long_context_window: int = 8192

    # --- mlp ---
    mlp_type: str = "swiglu"         # swiglu | gelu

    # --- moe ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_dispatch: str = "onehot"     # onehot (baseline) | sort (optimized)
    moe_groups: int = 1              # dispatch groups (launcher sets this to
                                     # the data-axis size so per-group
                                     # capacity stays device-local)

    # --- ssm / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_compute_dtype: str = "fp32"  # "bf16": intra-chunk matmuls in bf16
                                     # (state/decay stay fp32) — §Perf knob
    attn_every: int = 0              # hybrid: shared attn block every N layers
    slstm_every: int = 0             # xlstm: sLSTM block every N layers

    # --- frontends (stubbed modalities) ---
    frontend: str = ""               # '' | 'vision_stub' | 'audio_stub'
    n_frontend_tokens: int = 0       # patch / frame embeddings fed by input_specs

    # --- enc-dec ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0

    # --- training ---
    tie_embeddings: bool = True
    remat: bool = True
    seq_shard: bool = False          # Megatron-style sequence parallelism
                                     # (big stacks: saved remat activations
                                     # divide by the model-axis size)
    train_microbatches: int = 1      # gradient accumulation (activation
                                     # peak divides by this)
    attn_impl: str = "xla_chunked"   # xla_chunked | xla_full | pallas
    attn_chunk: int = 1024
    causal_skip: bool = False        # skip fully-masked kv blocks (perf opt)

    # --- cost-measurement knobs (dry-run delta method; see launch/dryrun) ---
    # XLA's cost_analysis counts while-loop bodies ONCE, so scanned layer
    # stacks under-report flops by ~n_layers.  The dry-run compiles small
    # UNROLLED variants (scan_layers=False, scan_chunks=False) to measure
    # exact per-layer costs and extrapolates; the full scanned compile is
    # still used for memory analysis and the multi-pod lowering proof.
    scan_layers: bool = True
    scan_chunks: bool = True

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0

    @property
    def d_head(self) -> int:
        return self.head_dim

    @property
    def is_recurrent(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def supports_shape(self, shape_name: str) -> bool:
        """Which assigned input shapes this arch runs (skips per DESIGN.md)."""
        if shape_name == "long_500k":
            # enc-dec full-attention: no meaningful 500k decode (DESIGN.md)
            return not self.is_encoder_decoder
        return True


# The four assigned input shapes.
@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
