"""zamba2-2.7b — Mamba2 backbone + one SHARED attention block.

[arXiv:2411.15242]  54L d_model=2560 (Mamba2, ssm_state=64) + a shared
full-attention block (32H MHA, d_ff=10240 MLP) applied every 6 layers
with shared parameters (the Zamba trick).  Simplification vs release:
per-invocation LoRA deltas on the shared block are omitted (DESIGN.md).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10_240, vocab_size=32_000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    attn_every=6, mlp_type="gelu", seq_shard=True, train_microbatches=2,
)

SMOKE = ArchConfig(
    name="zamba2-2.7b-smoke", family="hybrid",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=512,
    ssm_state=16, ssm_head_dim=32, ssm_expand=2, ssm_chunk=32,
    attn_every=2, mlp_type="gelu",
)
