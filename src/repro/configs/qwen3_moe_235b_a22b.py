"""qwen3-moe-235b-a22b — 128 routed experts, top-8, QK-norm.

[hf:Qwen/Qwen3-30B-A3B family scaled]  94L d_model=4096 64H (GQA kv=4)
d_ff=1536(expert) vocab=151936.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab_size=151_936,
    n_experts=128, n_shared_experts=0, top_k=8, d_ff_expert=1536,
    qk_norm=True, mlp_type="swiglu", rope_theta=1e6, head_dim=128,
    seq_shard=True, train_microbatches=4,
)

SMOKE = ArchConfig(
    name="qwen3-moe-235b-a22b-smoke", family="moe",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab_size=512,
    n_experts=4, n_shared_experts=0, top_k=2, d_ff_expert=96,
    qk_norm=True, mlp_type="swiglu", head_dim=32,
)
