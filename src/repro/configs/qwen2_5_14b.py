"""qwen2.5-14b — GQA with QKV bias.

[hf:Qwen/Qwen2.5 family]  48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=13_824, vocab_size=152_064,
    qkv_bias=True, mlp_type="swiglu", rope_theta=1e6, seq_shard=True, train_microbatches=4,
)

SMOKE = ArchConfig(
    name="qwen2.5-14b-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab_size=512,
    qkv_bias=True, mlp_type="swiglu",
)
