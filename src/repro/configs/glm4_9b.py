"""glm4-9b — RoPE + GQA decoder.

[hf:THUDM/glm-4-9b]  40L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=151552.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13_696, vocab_size=151_552,
    mlp_type="swiglu", rope_theta=1e4, seq_shard=True, train_microbatches=4,
)

SMOKE = ArchConfig(
    name="glm4-9b-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab_size=512,
    mlp_type="swiglu",
)
