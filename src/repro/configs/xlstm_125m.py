"""xlstm-125m — alternating sLSTM + mLSTM blocks.

[arXiv:2405.04517]  12L d_model=768 4H d_ff=0 vocab=50304.
d_ff=0: xLSTM blocks carry their own up-projections (mLSTM expand=2,
sLSTM proj 4/3).  sLSTM every 4th block (1:3 ratio, cf. xLSTM[7:1]/[1:1]
ablations), the rest mLSTM.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50_304,
    slstm_every=4, ssm_expand=2, ssm_chunk=256,
)

SMOKE = ArchConfig(
    name="xlstm-125m-smoke", family="ssm",
    n_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
    d_ff=0, vocab_size=512,
    slstm_every=2, ssm_expand=2, ssm_chunk=32,
)
