"""whisper-tiny — encoder-decoder ASR backbone, conv frontend STUB.

[arXiv:2212.04356]  4L(enc)+4L(dec) d_model=384 6H d_ff=1536 vocab=51865.
The mel-spectrogram + conv feature extractor is a STUB per the brief:
input_specs() provides 1500 precomputed frame embeddings.
No long_500k shape (enc-dec full attention; skip noted in DESIGN.md).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab_size=51_865,
    mlp_type="gelu",
    is_encoder_decoder=True, n_encoder_layers=4,
    frontend="audio_stub", n_frontend_tokens=1500,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="whisper-tiny-smoke", family="audio",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=512,
    mlp_type="gelu",
    is_encoder_decoder=True, n_encoder_layers=2,
    frontend="audio_stub", n_frontend_tokens=64,
)
