"""Assigned-architecture configs (``--arch <id>``) + the paper's GBDT config.

Every module exports CONFIG (exact assigned numbers, cited) and SMOKE
(reduced same-family variant for CPU tests).
"""

from __future__ import annotations

from .base import ArchConfig, InputShape, INPUT_SHAPES

_MODULES = {
    "deepseek-moe-16b": "deepseek_moe_16b",
    "granite-34b": "granite_34b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "internvl2-1b": "internvl2_1b",
    "granite-20b": "granite_20b",
    "xlstm-125m": "xlstm_125m",
    "qwen2.5-14b": "qwen2_5_14b",
    "whisper-tiny": "whisper_tiny",
    "glm4-9b": "glm4_9b",
    "zamba2-2.7b": "zamba2_2_7b",
}

ARCH_NAMES = list(_MODULES)


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    import importlib
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.SMOKE if smoke else mod.CONFIG


__all__ = ["ArchConfig", "InputShape", "INPUT_SHAPES", "ARCH_NAMES",
           "get_config"]
