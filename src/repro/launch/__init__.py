"""Launch layer: meshes, sharding rules, step factories, dry-run, trainers.

NOTE: do not import .dryrun from here — it sets XLA_FLAGS at import time
(512 placeholder devices) and must only be imported by the dry-run entry
point itself.
"""

from . import mesh, roofline, shardings, specs, steps

__all__ = ["mesh", "roofline", "shardings", "specs", "steps"]
