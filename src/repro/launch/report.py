"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the
dry-run artifacts, the §Telemetry table from the fit50 record in
BENCH_gbdt_step.json (the TrainReport summary written by
``benchmarks/bench_gbdt_step.py --update``), and the §Predict table
from BENCH_predict.json (the PredictReport summaries written by
``benchmarks/bench_predict.py --update``).

Usage: python -m repro.launch.report [--dir experiments/dryrun]
                  [--section dryrun|roofline|telemetry|predict|all]
Prints markdown to stdout (the EXPERIMENTS.md sections are refreshed by
piping this output).
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirname: str) -> list[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fmt_bytes(b) -> str:
    if b is None:
        return "-"
    return f"{b / 2**30:.2f}"


def dryrun_table(recs: list[dict]) -> str:
    out = ["| arch | shape | mesh | status | lower+compile s | "
           "arg GB/dev | temp GB/dev | collective bytes/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        ma = r.get("memory_analysis", {})
        coll = r.get("collective_bytes") or r.get(
            "collective_bytes_scanned_raw", {})
        tot_coll = sum(v for v in coll.values()) if coll else 0
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} | "
            f"{r.get('lower_s', 0)}+{r.get('compile_s', 0)} | "
            f"{fmt_bytes(ma.get('argument_size_in_bytes'))} | "
            f"{fmt_bytes(ma.get('temp_size_in_bytes'))} | "
            f"{tot_coll:.3g} |")
    return "\n".join(out)


def roofline_table(recs: list[dict]) -> str:
    out = ["| arch | shape | compute ms | memory ms | collective ms | "
           "dominant | useful-FLOPs ratio | params |",
           "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("mesh") != "pod16x16" or "roofline" not in r:
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{rf['compute_s']*1e3:.2f} | {rf['memory_s']*1e3:.2f} | "
            f"{rf['collective_s']*1e3:.2f} | **{rf['dominant']}** | "
            f"{r.get('useful_flops_ratio', 0):.2f} | "
            f"{r.get('n_params', 0)/1e9:.2f}B |")
    skips = [r for r in recs if r.get("status") == "skipped"]
    for r in skips:
        out.append(f"| {r['arch']} | {r['shape']} | - | - | - | skipped | "
                   f"- | {r.get('reason', '')} |")
    return "\n".join(out)


def telemetry_table(rec: dict) -> str:
    """Markdown view of the BENCH_gbdt_step.json telemetry block."""
    tel = rec.get("telemetry")
    if not tel:
        return "(no telemetry block — rerun bench_gbdt_step.py --update)"
    s = tel["summary"]
    wl = rec.get("workload", {})
    out = ["| workload | warm fit s | overhead vs plain | loss first→final | "
           "splits total | best gain max |",
           "|---|---|---|---|---|---|",
           f"| n={wl.get('n')} T={wl.get('n_trees')} "
           f"d={wl.get('max_depth')} | {tel['warm_fit_s']} | "
           f"{tel['overhead_pct_vs_scanned_warm']:+.1f}% | "
           f"{s['train_loss']['first']:.4f}→{s['train_loss']['final']:.4f} | "
           f"{s['splits']['total']} | {s['best_gain']['max']:.2f} |"]
    su = rec.get("scatter_updates")
    if su:
        out += ["", "| scatter updates direct | subtract | reduction |",
                "|---|---|---|",
                f"| {su['direct_total']:.0f} | {su['subtract_total']:.0f} | "
                f"{su['reduction_ratio']:.2f}x |"]
    return "\n".join(out)


def predict_table(rec: dict) -> str:
    """Markdown view of BENCH_predict.json (repro.obs.PredictReport
    summaries per engine variant + the per-tree-scan baseline)."""
    variants = rec.get("variants")
    if not variants:
        return "(no variants block — rerun bench_predict.py --update)"
    wl = rec.get("workload", {})
    out = [f"workload: {wl.get('n_trees')} trees x depth "
           f"{wl.get('max_depth')}, {wl.get('rows')} rows x "
           f"{wl.get('n_features')} features (chunk "
           f"{wl.get('tree_chunk')})", "",
           "| engine | rows/s | p50 ms | p99 ms | speedup vs scan |",
           "|---|---|---|---|---|"]
    for name, v in variants.items():
        s = v["summary"]
        speed = s.get("speedup_vs_scan")
        out.append(
            f"| {name} | {s['rows_per_s']:,.0f} | "
            f"{s['latency_ms']['p50']:.2f} | {s['latency_ms']['p99']:.2f} | "
            f"{'-' if speed is None else f'{speed:.1f}x'} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--section",
                    choices=["dryrun", "roofline", "telemetry", "predict",
                             "both", "all"],
                    default="both")
    ap.add_argument("--bench-json",
                    default=os.path.join(os.path.dirname(__file__), "..",
                                         "..", "..", "BENCH_gbdt_step.json"),
                    help="fit50 record for the telemetry section")
    ap.add_argument("--predict-json",
                    default=os.path.join(os.path.dirname(__file__), "..",
                                         "..", "..", "BENCH_predict.json"),
                    help="inference record for the predict section")
    args = ap.parse_args()
    recs = load(args.dir)
    if args.section in ("dryrun", "both", "all"):
        print("## §Dry-run\n")
        print(dryrun_table(recs))
        print()
    if args.section in ("roofline", "both", "all"):
        print("## §Roofline (single-pod 16x16, per-chip terms)\n")
        print(roofline_table(recs))
        print()
    if args.section in ("telemetry", "all"):
        print("## §Telemetry (fit50 TrainReport)\n")
        with open(args.bench_json) as fh:
            print(telemetry_table(json.load(fh)))
    if args.section in ("predict", "all"):
        print("## §Predict (batched inference engine)\n")
        with open(args.predict_json) as fh:
            print(predict_table(json.load(fh)))


if __name__ == "__main__":
    main()
