import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing harness: re-run a dry-run combo with config overrides
and report the roofline-term deltas against the recorded baseline.

Usage:
  python -m repro.launch.hillclimb --arch zamba2-2.7b --shape train_4k \
      --set train_microbatches=1 --set seq_shard=False --tag mb1_noseq
"""

import argparse
import dataclasses
import json

from ..configs import INPUT_SHAPES, get_config
from . import dryrun


def parse_override(s: str):
    k, v = s.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return k, v == "True"
    return k, v


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg overrides, e.g. --set train_microbatches=1")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--out-dir", default="experiments/hillclimb")
    ap.add_argument("--baseline-dir", default="experiments/dryrun")
    ap.add_argument("--fast", action="store_true",
                    help="skip the full scanned compile (no memory "
                         "analysis): measure per-unit costs from the "
                         "1- and 2-unit unrolled variants only")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    overrides = dict(parse_override(s) for s in args.set)
    cfg = dataclasses.replace(cfg, **overrides)

    if args.fast:
        # c1-only protocol: compile ONLY the 1-unit unrolled variant and
        # compare against the baseline's recorded delta_detail.c1.  Exact
        # for per-layer effects (which is what every §Perf change here
        # targets); ~10x faster than the full delta on the hybrid archs.
        import jax
        from .mesh import make_production_mesh
        shape = INPUT_SHAPES[args.shape]
        if cfg.family == "moe" and cfg.moe_groups == 1:
            cfg2 = dataclasses.replace(cfg, moe_groups=16)
        else:
            cfg2 = cfg
        mesh = make_production_mesh()
        c1 = dryrun._compile_cost(dryrun._delta_cfg(cfg2, 1), shape, mesh)
        rec = {"arch": args.arch, "shape": args.shape, "status": "ok",
               "c1": c1}
        base_path = os.path.join(
            args.baseline_dir,
            f"{args.arch}__{args.shape}__pod16x16.json")
        base = json.load(open(base_path))
        b1 = base["delta_detail"]["c1"]
        rec["tag"] = args.tag
        rec["overrides"] = overrides
        os.makedirs(args.out_dir, exist_ok=True)
        with open(os.path.join(
                args.out_dir,
                f"{args.arch}__{args.shape}__{args.tag}.json"), "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[hillclimb-c1] {args.arch} x {args.shape} [{args.tag}] "
              f"{overrides}")
        for k in ("flops", "bytes"):
            d = (c1[k] - b1[k]) / b1[k] * 100 if b1[k] else 0.0
            print(f"  c1 {k:6s} {c1[k]:.4g}  baseline {b1[k]:.4g}  "
                  f"({d:+.1f}%)")
        cb = sum(c1["coll"].values())
        bb = sum(b1["coll"].values())
        print(f"  c1 coll   {cb:.4g}  baseline {bb:.4g}  "
              f"({(cb-bb)/bb*100 if bb else 0:+.1f}%)")
        return
    else:
        rec = dryrun.run_one(args.arch, args.shape, multi_pod=False,
                             cfg=cfg, out_dir=None, verbose=False)
    rec["tag"] = args.tag
    rec["overrides"] = overrides
    os.makedirs(args.out_dir, exist_ok=True)
    fname = f"{args.arch}__{args.shape}__{args.tag}.json"
    with open(os.path.join(args.out_dir, fname), "w") as f:
        json.dump(rec, f, indent=1)

    base_path = os.path.join(
        args.baseline_dir, f"{args.arch}__{args.shape}__pod16x16.json")
    base = json.load(open(base_path)) if os.path.exists(base_path) else None

    if rec["status"] != "ok":
        print(f"[hillclimb] {args.tag}: ERROR {rec.get('error')}")
        print(rec.get("traceback", "")[-1500:])
        raise SystemExit(1)

    r = rec["roofline"]
    print(f"[hillclimb] {args.arch} x {args.shape} [{args.tag}] "
          f"{overrides}")
    for term in ("compute_s", "memory_s", "collective_s"):
        line = f"  {term:13s} {r[term]*1e3:10.2f} ms"
        if base and "roofline" in base:
            b = base["roofline"][term]
            if b > 0:
                line += f"   ({(r[term]-b)/b*100:+.1f}% vs baseline)"
        print(line)
    ma = rec.get("memory_analysis", {})
    print(f"  temp GB/dev   {ma.get('temp_size_in_bytes', 0)/2**30:10.1f}"
          f"   arg GB/dev {ma.get('argument_size_in_bytes', 0)/2**30:.1f}")
    print(f"  dominant      {r['dominant']}")


if __name__ == "__main__":
    main()
