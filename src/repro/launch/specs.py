"""Input/state ShapeDtypeStruct stand-ins + their PartitionSpecs.

``input_specs`` returns weak-type-correct, shardable ShapeDtypeStructs for
every model input of an (arch x input-shape) combination — no device
allocation, which is what lets the 512-chip dry-run run on one CPU.

The decode-state specs mirror :func:`repro.models.model.init_decode_state`
structure explicitly (no heuristics).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, InputShape
from ..models import layers, model, ssm
from .mesh import batch_axes
from .shardings import maybe


def _batch_axis(mesh, b: int):
    axes = batch_axes(mesh)
    return maybe(tuple(axes) if len(axes) > 1 else axes[0], b, mesh)


def decode_window(cfg: ArchConfig, shape: InputShape) -> int:
    """Sliding window for the decode path (long_500k on quadratic archs)."""
    if shape.name == "long_500k" and not cfg.is_recurrent:
        return cfg.long_context_window
    return cfg.sliding_window


def cache_len(cfg: ArchConfig, shape: InputShape) -> int:
    w = decode_window(cfg, shape)
    return min(shape.seq_len, w) if w > 0 else shape.seq_len


def input_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    """ShapeDtypeStructs for the step function's data arguments."""
    b = shape.global_batch
    if shape.kind in ("train", "prefill"):
        out = {"tokens": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32)}
        if cfg.family == "vlm":
            out["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frontend_tokens, cfg.d_model), layers.COMPUTE_DTYPE)
        if cfg.family == "audio":
            out["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frontend_tokens, cfg.d_model), layers.COMPUTE_DTYPE)
        return out
    # decode: one new token against a seq_len-sized cache/state
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((b,), jnp.int32)}


def input_shardings(specs: dict, mesh) -> dict:
    out = {}
    for k, v in specs.items():
        ba = _batch_axis(mesh, v.shape[0])
        out[k] = NamedSharding(mesh, P(ba, *([None] * (len(v.shape) - 1))))
    return out


def decode_state_specs(cfg: ArchConfig, shape: InputShape):
    """(ShapeDtypeStruct tree, NamedSharding-spec tree) for decode state."""
    b = shape.global_batch
    L = cache_len(cfg, shape)
    state = jax.eval_shape(lambda: model.init_decode_state(cfg, b, L))
    return state


def decode_state_shardings(cfg: ArchConfig, shape: InputShape, mesh):
    b = shape.global_batch
    ba = _batch_axis(mesh, b)
    mm = maybe("model", cfg.n_kv_heads, mesh)
    # few-kv-head archs (MQA/GQA<16): shard the head_dim instead so the
    # 32k cache still divides across the tensor-parallel axis
    md = None if mm is not None else maybe("model", cfg.head_dim, mesh)

    def kv_spec(rank):
        # (layers?, B, L, Hkv, Dh)
        lead = [None] * (rank - 4)
        return P(*lead, ba, None, mm, md)

    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        return {"kv": {"k": NamedSharding(mesh, kv_spec(5)),
                       "v": NamedSharding(mesh, kv_spec(5))}}
    if fam == "ssm":
        _, nh_m = ssm.mlstm_dims(cfg)
        mh = maybe("model", cfg.n_heads, mesh)
        sl = tuple(NamedSharding(mesh, P(None, ba, mh) if r == 3
                                 else P(None, ba, mh, None))
                   for r in (4, 4, 4, 3))
        return {"mlstm": NamedSharding(mesh, P(None, None, ba, mh, None, None)),
                "slstm": sl}
    if fam == "hybrid":
        _, nh = ssm.mamba2_dims(cfg)
        mh = maybe("model", nh, mesh)
        return {"mamba": NamedSharding(mesh, P(None, None, ba, mh, None, None)),
                "kv": {"k": NamedSharding(mesh, kv_spec(5)),
                       "v": NamedSharding(mesh, kv_spec(5))}}
    if fam == "audio":
        cross = NamedSharding(mesh, kv_spec(5))
        return {"kv": {"k": NamedSharding(mesh, kv_spec(5)),
                       "v": NamedSharding(mesh, kv_spec(5))},
                "cross_k": cross, "cross_v": cross}
    raise ValueError(fam)
