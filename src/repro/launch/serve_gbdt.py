"""GBDT serving entry point: microbatched batched-forest inference.

Drives the level-synchronous inference engine
(:mod:`repro.core.predict`) the way a serving process would: a stream
of fixed-size microbatches through ONE warmed-up compiled traversal,
per-request wall-clock latencies, p50/p99 + rows/s summarized as a
:class:`repro.obs.PredictReport`.

Usage:
  PYTHONPATH=src python -m repro.launch.serve_gbdt \
      --trees 500 --depth 6 --features 32 --microbatch 4096 \
      --requests 32 --backend auto [--binned] [--ckpt model.npz] \
      [--data-shards N] [--json predict_report.json]

With ``--ckpt`` the model comes from :func:`repro.checkpoint.load_gbdt`
(the full serving round-trip); otherwise a synthetic forest of the
requested shape is built — serving performance depends on tree count /
depth / row count, not on the leaf values being meaningful.

``--data-shards`` lays each microbatch out row-sharded across a
``(data, model)`` debug mesh (:func:`repro.launch.mesh.make_debug_mesh`)
before predicting — the engine is elementwise in rows, so jit
partitions the traversal without any annotation in the model code.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core import boosting, tree as tree_lib
from ..core.predict import DEFAULT_TREE_CHUNK
from ..obs import PredictReport
from . import mesh as mesh_lib


def synthetic_gbdt(*, n_trees: int, max_depth: int, n_features: int,
                   n_candidates: int = 32, seed: int = 0,
                   passthrough_frac: float = 0.1,
                   **config_overrides) -> boosting.GBDTModel:
    """A random-but-valid GBDTModel of the requested shape.

    Valid means the trained-model invariants hold, so every predict
    path (raw, binned, oracle scan) agrees on it: candidates are a
    fixed sorted grid, each internal node's threshold IS
    ``candidates[feature, split_bin]``, and passthrough nodes carry the
    (-1, +inf, last-bin) sentinel triple.  Used by the serving
    entry point and ``benchmarks/bench_predict.py`` — inference cost
    depends on the forest's shape, not on how it was fit.
    """
    rng = np.random.default_rng(seed)
    f, k = n_features, n_candidates
    n_inner, n_leaves = 2 ** max_depth - 1, 2 ** max_depth
    cands = np.sort(rng.normal(size=(f, k)).astype(np.float32), axis=1)

    feature = rng.integers(0, f, size=(n_trees, n_inner)).astype(np.int32)
    split_bin = rng.integers(0, k, size=(n_trees, n_inner)).astype(np.int32)
    passthrough = rng.random(size=(n_trees, n_inner)) < passthrough_frac
    feature = np.where(passthrough, -1, feature).astype(np.int32)
    split_bin = np.where(passthrough, k, split_bin).astype(np.int32)
    threshold = cands[feature.clip(0), split_bin.clip(max=k - 1)]
    threshold = np.where(passthrough, np.inf, threshold).astype(np.float32)
    leaf_value = (0.1 * rng.normal(size=(n_trees, n_leaves))
                  ).astype(np.float32)

    cfg = boosting.GBDTConfig(
        n_trees=n_trees, max_depth=max_depth, n_candidates=k,
        repropose_each_round=False, **config_overrides)
    forest = tree_lib.Forest(
        feature=jnp.asarray(feature), split_bin=jnp.asarray(split_bin),
        threshold=jnp.asarray(threshold), leaf_value=jnp.asarray(leaf_value))
    return boosting.GBDTModel(config=cfg, forest=forest, base_score=0.0,
                              candidates=jnp.asarray(cands)[None])


def serve(model: boosting.GBDTModel, *, microbatch: int = 4096,
          n_requests: int = 32, binned: bool = False,
          backend: str | None = None, tree_chunk: int | None = None,
          data_shards: int = 0, seed: int = 0,
          output: str = "margin") -> PredictReport:
    """Run the microbatched serving loop and return its telemetry.

    Warmup: the first microbatch is predicted twice before timing
    starts — that traces + compiles the traversal (and, binned, the
    binning) so every measured request hits the executable cache.
    """
    cfg = model.config
    f = model.forest  # noqa: F841  (keep the forest resident)
    n_features = (model.bin_edges.shape[0] if model.bin_edges is not None
                  else int(jnp.max(model.forest.feature)) + 1)
    rng = np.random.default_rng(seed)
    batches = [rng.normal(size=(microbatch, n_features)).astype(np.float32)
               for _ in range(n_requests)]

    sharding = None
    if data_shards:
        m = mesh_lib.make_debug_mesh(n_data=data_shards, n_model=1)
        sharding = jax.sharding.NamedSharding(
            m, jax.sharding.PartitionSpec("data"))

    def request(xb: np.ndarray) -> jax.Array:
        if sharding is not None:
            xb = jax.device_put(xb, sharding)
        return model.predict(xb, output=output, binned=binned,
                             backend=backend, tree_chunk=tree_chunk)

    # warmup: compile the whole request path outside the timed loop
    for _ in range(2):
        request(batches[0]).block_until_ready()

    lat = np.empty((n_requests,), np.float64)
    for i, xb in enumerate(batches):
        t0 = time.perf_counter()
        request(xb).block_until_ready()
        lat[i] = time.perf_counter() - t0

    return PredictReport(
        latencies_s=lat, rows_per_request=microbatch,
        engine={
            "n_trees": cfg.n_trees, "max_depth": cfg.max_depth,
            "n_features": int(n_features),
            "tree_chunk": tree_chunk or DEFAULT_TREE_CHUNK,
            "backend": backend or cfg.backend, "binned": bool(binned),
            "data_shards": int(data_shards),
        })


def main(argv=None) -> PredictReport:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--ckpt", default=None,
                   help="serve a checkpointed model (repro.checkpoint)")
    p.add_argument("--trees", type=int, default=500)
    p.add_argument("--depth", type=int, default=6)
    p.add_argument("--features", type=int, default=32)
    p.add_argument("--candidates", type=int, default=32)
    p.add_argument("--microbatch", type=int, default=4096)
    p.add_argument("--requests", type=int, default=32)
    p.add_argument("--backend", default=None,
                   help="auto|pallas|interpret|ref|packed")
    p.add_argument("--tree-chunk", type=int, default=None)
    p.add_argument("--binned", action="store_true",
                   help="traverse on bin ids (binning timed per request)")
    p.add_argument("--data-shards", type=int, default=0,
                   help="row-shard each microbatch over a debug mesh")
    p.add_argument("--output", default="margin",
                   choices=["margin", "proba", "label"])
    p.add_argument("--json", default=None,
                   help="write the PredictReport JSON here")
    args = p.parse_args(argv)

    if args.ckpt:
        from ..checkpoint import load_gbdt
        model = load_gbdt(args.ckpt)
    else:
        model = synthetic_gbdt(n_trees=args.trees, max_depth=args.depth,
                               n_features=args.features,
                               n_candidates=args.candidates)

    report = serve(model, microbatch=args.microbatch,
                   n_requests=args.requests, binned=args.binned,
                   backend=args.backend, tree_chunk=args.tree_chunk,
                   data_shards=args.data_shards, output=args.output)
    s = report.summarize()
    print(f"[serve_gbdt] {report.engine['n_trees']} trees x depth "
          f"{report.engine['max_depth']} | {s['rows_per_request']} rows/req "
          f"x {s['n_requests']} req | backend={report.engine['backend']}"
          f"{' binned' if report.engine['binned'] else ''}", flush=True)
    print(f"[serve_gbdt] {s['rows_per_s']:,.0f} rows/s | p50 "
          f"{s['latency_ms']['p50']:.2f} ms | p99 "
          f"{s['latency_ms']['p99']:.2f} ms", flush=True)
    if args.json:
        report.to_json(args.json)
        print(f"[serve_gbdt] wrote {args.json}", flush=True)
    return report


if __name__ == "__main__":
    main()
