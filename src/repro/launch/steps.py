"""Jittable train / prefill / serve step factories.

These close over the static ArchConfig and return functions whose
arguments are pure pytrees of arrays — the objects the launcher jits,
shards, lowers and (on the dry-run path) compiles without allocation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..models import model
from ..optim import AdamWConfig, adamw_init, adamw_update


def make_train_step(cfg, opt_cfg: AdamWConfig, *, window: int = 0,
                    microbatches: int = 1, grad_shardings=None):
    """Training step with optional gradient accumulation.

    microbatches > 1 splits the global batch into that many sequential
    microbatches (scanned, each rematerialised), dividing activation peak
    memory — grads are accumulated in fp32 and the optimizer runs once.

    grad_shardings: optional pytree of shardings to pin the accumulated
    grads to (ZeRO-style reduce-scatter instead of per-microbatch
    all-reduce; see EXPERIMENTS.md §Perf).
    """
    def grad_of(params, batch):
        def lf(p):
            return model.loss_fn(p, cfg, batch, window=window)
        return jax.value_and_grad(lf, has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, (xent, aux)), grads = grad_of(params, batch)
        else:
            mb = jax.tree.map(
                lambda a: a.reshape(microbatches, a.shape[0] // microbatches,
                                    *a.shape[1:]), batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc(carry, b):
                g, loss, xent, aux = carry
                (l, (x, a)), gi = grad_of(params, b)
                if grad_shardings is not None:
                    gi = jax.tree.map(jax.lax.with_sharding_constraint,
                                      gi, grad_shardings)
                g = jax.tree.map(lambda u, v: u + v.astype(jnp.float32),
                                 g, gi)
                return (g, loss + l, xent + x, aux + a), None

            carry = (g0, 0.0, 0.0, 0.0)
            if cfg.scan_chunks:
                carry, _ = jax.lax.scan(acc, carry, mb)
            else:  # unrolled for dry-run cost measurement
                for i in range(microbatches):
                    carry, _ = acc(carry, jax.tree.map(lambda a: a[i], mb))
            grads, loss, xent, aux = carry
            grads = jax.tree.map(lambda gr: gr / microbatches, grads)
            loss, xent, aux = (v / microbatches for v in (loss, xent, aux))
        params, opt_state, gnorm = adamw_update(params, grads, opt_state,
                                                opt_cfg)
        metrics = {"loss": loss, "xent": xent, "aux": aux, "gnorm": gnorm}
        return params, opt_state, metrics
    return train_step


def make_prefill_step(cfg, *, window: int = 0):
    def prefill_step(params, batch):
        logits, _ = model.forward(params, cfg, batch, window=window)
        return logits
    return prefill_step


def make_serve_step(cfg, *, window: int = 0):
    def serve_step(params, state, tokens, pos):
        logits, state = model.decode_step(params, cfg, state, tokens, pos,
                                          window=window)
        return logits, state
    return serve_step


def init_train_state(cfg, key, opt_cfg: AdamWConfig):
    params = model.init_params(cfg, key)
    return params, adamw_init(params)


def train_state_shapes(cfg, opt_cfg: AdamWConfig):
    """ShapeDtypeStructs of (params, opt_state) — no allocation."""
    def f():
        return init_train_state(cfg, jax.random.PRNGKey(0), opt_cfg)
    return jax.eval_shape(f)
