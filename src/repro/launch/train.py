"""Training launcher: real steps on the available devices.

On CPU (tests/demo) this trains a REDUCED config; on a TPU slice the same
entry point drives the full mesh.  The production 512-chip configuration
is validated by dryrun.py (lower+compile only).

Usage:
  python -m repro.launch.train --arch glm4-9b --smoke --steps 20
  python -m repro.launch.train --arch xlstm-125m --smoke --steps 50 \
      --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import latest_step, restore_checkpoint, save_checkpoint
from ..configs import ARCH_NAMES, get_config
from ..data import TokenPipeline
from ..models import model
from ..optim import AdamWConfig, adamw_init
from . import steps


def make_batch_fn(cfg, batch: int, seq: int, seed: int = 0):
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=seq,
                         global_batch=batch, seed=seed)

    def fn(step: int) -> dict:
        b = dict(pipe.batch_at(step))
        key = jax.random.fold_in(jax.random.PRNGKey(seed + 1), step)
        if cfg.family == "vlm":
            b["patches"] = jax.random.normal(
                key, (batch, cfg.n_frontend_tokens, cfg.d_model),
                jnp.bfloat16)
        if cfg.family == "audio":
            b["frames"] = jax.random.normal(
                key, (batch, cfg.n_frontend_tokens, cfg.d_model),
                jnp.bfloat16)
        return b
    return fn


def train(arch: str, *, smoke: bool = True, steps_n: int = 20,
          batch: int = 4, seq: int = 128, lr: float = 1e-3,
          ckpt_dir: str | None = None, ckpt_every: int = 0,
          microbatches: int = 1, log_every: int = 5) -> list[float]:
    cfg = get_config(arch, smoke=smoke)
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=min(10, steps_n // 4),
                          total_steps=steps_n)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    start = 0
    if ckpt_dir and (s := latest_step(ckpt_dir)) is not None:
        import os
        tgt = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                           {"params": params, "opt": opt})
        state = restore_checkpoint(
            os.path.join(ckpt_dir, f"step_{s:08d}.npz"), tgt)
        params, opt = state["params"], state["opt"]
        start = s
        print(f"[train] restored step {s} from {ckpt_dir}")

    step_fn = jax.jit(steps.make_train_step(cfg, opt_cfg,
                                            microbatches=microbatches))
    batch_fn = make_batch_fn(cfg, batch, seq)
    losses = []
    t0 = time.time()
    for i in range(start, steps_n):
        params, opt, metrics = step_fn(params, opt, batch_fn(i))
        losses.append(float(metrics["loss"]))
        if i % log_every == 0 or i == steps_n - 1:
            print(f"[train] {arch} step={i:4d} loss={losses[-1]:.4f} "
                  f"gnorm={float(metrics['gnorm']):.3f} "
                  f"({(time.time() - t0):.1f}s)", flush=True)
        if ckpt_dir and ckpt_every and (i + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_dir, i + 1, {"params": params, "opt": opt})
    return losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args()
    losses = train(args.arch, smoke=args.smoke, steps_n=args.steps,
                   batch=args.batch, seq=args.seq, lr=args.lr,
                   ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                   microbatches=args.microbatches)
    print(f"[train] done: first={losses[0]:.4f} last={losses[-1]:.4f}")


if __name__ == "__main__":
    main()
