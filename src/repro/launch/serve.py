"""Serving launcher: prefill + batched greedy decode with a KV cache.

Usage:
  python -m repro.launch.serve --arch glm4-9b --smoke --batch 4 \
      --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import ARCH_NAMES, get_config
from ..models import attention as attn_lib
from ..models import model
from . import steps


def prefill_into_cache(params, cfg, batch, cache_len: int):
    """Run the decode path token-by-token over the prompt (simple,
    family-agnostic prefill; the attention-only fast path is
    model.forward)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    state = model.init_decode_state(cfg, b, cache_len)
    if cfg.family == "audio":
        # encode the (stub) frames once and cache per-layer cross K/V
        from ..models import layers as L
        enc = model._encode_audio(params, cfg, batch["frames"])
        f = enc.shape[1]

        def kv(cp):
            k = L.linear(cp["attn"]["wk"], enc).reshape(
                b, f, cfg.n_kv_heads, cfg.head_dim)
            v = L.linear(cp["attn"]["wv"], enc).reshape(
                b, f, cfg.n_kv_heads, cfg.head_dim)
            return k, v
        ks, vs = jax.vmap(kv)(params["cross_layers"])
        state["cross_k"] = ks.astype(state["cross_k"].dtype)
        state["cross_v"] = vs.astype(state["cross_v"].dtype)
    serve = jax.jit(steps.make_serve_step(cfg))
    logits = None
    for t in range(s):
        logits, state = serve(params, state,
                              tokens[:, t:t + 1],
                              jnp.full((b,), t, jnp.int32))
    return logits, state, s


def generate(arch: str, *, smoke: bool = True, batch: int = 4,
             prompt_len: int = 32, gen: int = 16,
             seed: int = 0) -> jnp.ndarray:
    cfg = get_config(arch, smoke=smoke)
    key = jax.random.PRNGKey(seed)
    params = model.init_params(cfg, key)
    cache_len = prompt_len + gen
    prompts = jax.random.randint(key, (batch, prompt_len), 0,
                                 cfg.vocab_size)
    b = {"tokens": prompts}
    if cfg.family == "audio":
        b["frames"] = jax.random.normal(
            key, (batch, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    t0 = time.time()
    logits, state, pos0 = prefill_into_cache(params, cfg, b, cache_len)
    print(f"[serve] {arch} prefill {prompt_len} tokens x{batch} "
          f"in {time.time() - t0:.1f}s", flush=True)

    serve = jax.jit(steps.make_serve_step(cfg))
    out = [jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)]
    t0 = time.time()
    for t in range(gen - 1):
        logits, state = serve(params, state, out[-1],
                              jnp.full((batch,), pos0 + t, jnp.int32))
        out.append(jnp.argmax(logits[:, -1:], -1).astype(jnp.int32))
    toks = jnp.concatenate(out, 1)
    dt = time.time() - t0
    print(f"[serve] generated {gen}x{batch} tokens in {dt:.1f}s "
          f"({gen * batch / max(dt, 1e-9):.1f} tok/s)", flush=True)
    return toks


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    toks = generate(args.arch, smoke=args.smoke, batch=args.batch,
                    prompt_len=args.prompt_len, gen=args.gen)
    print("[serve] sample tokens:", toks[0, :8].tolist())


if __name__ == "__main__":
    main()
