"""Production mesh definitions.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run forces 512 host devices via XLA_FLAGS
*before* any jax import; tests see the real single device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU integration tests (forced host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def n_batch_devices(mesh) -> int:
    out = 1
    for a in batch_axes(mesh):
        out *= mesh.shape[a]
    return out
