import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input shape) on the
production mesh, record memory/cost analysis + collective schedule.

The two lines above MUST stay the first statements in this module — jax
locks the device count on first init, and the dry-run (and only the
dry-run) needs 512 placeholder host devices.

Usage:
  python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out-dir experiments/dryrun]
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from .. import compat
from ..configs import ARCH_NAMES, INPUT_SHAPES, get_config
from ..models.sharding import logical_rules, rules_for_mesh
from ..optim import AdamWConfig
from . import roofline, specs, steps
from .mesh import make_production_mesh
from .shardings import (batch_shardings, opt_shardings, param_shardings)


def _mesh_tag(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def build_lowered(cfg, shape, mesh, opt_cfg=None, overrides=None):
    """Lower the right step function for (cfg, shape) on mesh."""
    opt_cfg = opt_cfg or AdamWConfig()
    if cfg.family == "moe" and cfg.moe_groups == 1:
        from .mesh import n_batch_devices
        cfg = dataclasses.replace(cfg, moe_groups=n_batch_devices(mesh))
    rules = rules_for_mesh(mesh, seq_shard=(cfg.seq_shard and
                                            shape.kind == "train"))
    window = specs.decode_window(cfg, shape)
    bspecs = specs.input_specs(cfg, shape)

    with compat.use_mesh(mesh), logical_rules(rules):
        if shape.kind == "train":
            pshapes, oshapes = steps.train_state_shapes(cfg, opt_cfg)
            pshard = param_shardings(pshapes, mesh, cfg)
            oshard = opt_shardings(oshapes, mesh, cfg)
            bshard = batch_shardings(bspecs, mesh)
            # pin accumulated grads to the ZeRO specs: per-microbatch grad
            # reductions become reduce-scatters instead of all-reduces
            fn = steps.make_train_step(
                cfg, opt_cfg, window=window,
                microbatches=cfg.train_microbatches,
                grad_shardings=(oshard["m"]
                                if cfg.train_microbatches > 1 else None))
            jitted = jax.jit(fn, in_shardings=(pshard, oshard, bshard),
                             out_shardings=(pshard, oshard, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(pshapes, oshapes, bspecs)
            state_shapes = (pshapes, oshapes)
        elif shape.kind == "prefill":
            pshapes, _ = steps.train_state_shapes(cfg, opt_cfg)
            pshard = param_shardings(pshapes, mesh, cfg)
            bshard = batch_shardings(bspecs, mesh)
            fn = steps.make_prefill_step(cfg, window=window)
            jitted = jax.jit(fn, in_shardings=(pshard, bshard))
            lowered = jitted.lower(pshapes, bspecs)
            state_shapes = (pshapes,)
        else:  # decode
            pshapes, _ = steps.train_state_shapes(cfg, opt_cfg)
            pshard = param_shardings(pshapes, mesh, cfg)
            sshapes = specs.decode_state_specs(cfg, shape)
            sshard = specs.decode_state_shardings(cfg, shape, mesh)
            bshard = batch_shardings(bspecs, mesh)
            fn = steps.make_serve_step(cfg, window=window)
            jitted = jax.jit(fn, in_shardings=(pshard, sshard,
                                               bshard["tokens"],
                                               bshard["pos"]),
                             out_shardings=(None, sshard),
                             donate_argnums=(1,))
            lowered = jitted.lower(pshapes, sshapes, bspecs["tokens"],
                                   bspecs["pos"])
            state_shapes = (pshapes,)
    return lowered, state_shapes


# ---------------------------------------------------------------------------
# Delta cost measurement.
#
# XLA's cost_analysis counts while-loop bodies ONCE, so the scanned layer
# stack under-reports flops/bytes/collectives by ~n_layers.  We compile two
# small UNROLLED variants (1 and 2 layer-groups, scan_layers=False,
# scan_chunks=False) and extrapolate:   total = c1 + (n_units - 1) * (c2 - c1).
# Embedding/unembedding/frontend costs appear in both and cancel exactly in
# the delta; per-unit costs are identical across a uniform stack, so the
# extrapolation is exact up to XLA fusion noise.  The only loop that cannot
# be unrolled is sLSTM's time recurrence — corrected analytically below.
# ---------------------------------------------------------------------------

_DELTA_ATTN_CHUNK = 4096   # fewer unrolled kv blocks; flops unchanged


def _n_units(cfg) -> int:
    if cfg.family == "ssm":
        return cfg.n_layers // cfg.slstm_every
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    return cfg.n_layers


def _delta_cfg(cfg, units: int):
    common = dict(scan_layers=False, scan_chunks=False,
                  attn_chunk=_DELTA_ATTN_CHUNK)
    if cfg.family == "ssm":
        return dataclasses.replace(cfg, n_layers=units * cfg.slstm_every,
                                   **common)
    if cfg.family == "hybrid":
        return dataclasses.replace(cfg, n_layers=units * cfg.attn_every,
                                   **common)
    if cfg.family == "audio":
        return dataclasses.replace(cfg, n_layers=units,
                                   n_encoder_layers=units, **common)
    return dataclasses.replace(cfg, n_layers=units, **common)


def _slstm_correction(cfg, shape) -> tuple[float, float]:
    """(flops, bytes) missing per sLSTM layer from its time-recurrence scan
    (body counted once; real trip count = seq_len)."""
    if cfg.family != "ssm" or shape.kind == "decode":
        return 0.0, 0.0
    b = shape.global_batch
    s = shape.seq_len
    h, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    per_step_flops = 8.0 * b * h * dh * dh + 30.0 * b * h * dh
    per_step_bytes = 4.0 * (h * dh * 4 * dh) + 4.0 * 8 * b * h * dh
    mult = 3.0 if shape.kind == "train" else 1.0     # bwd + remat fwd
    n_sl = cfg.n_layers // cfg.slstm_every
    return (mult * n_sl * (s - 1) * per_step_flops,
            mult * n_sl * (s - 1) * per_step_bytes)


def _compile_cost(cfg, shape, mesh) -> dict:
    lowered, _ = build_lowered(cfg, shape, mesh)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, list) else cost
    coll = roofline.collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll}


def measure_cost(cfg, shape, mesh) -> dict:
    """Extrapolated whole-model cost via the delta method."""
    c1 = _compile_cost(_delta_cfg(cfg, 1), shape, mesh)
    c2 = _compile_cost(_delta_cfg(cfg, 2), shape, mesh)
    n = _n_units(cfg)
    ext = lambda a, b: max(a + (n - 1) * (b - a), 0.0)
    flops = ext(c1["flops"], c2["flops"])
    byts = ext(c1["bytes"], c2["bytes"])
    coll = {k: ext(c1["coll"][k], c2["coll"][k]) for k in c1["coll"]}
    sl_f, sl_b = _slstm_correction(cfg, shape)
    return {"flops": flops + sl_f, "bytes": byts + sl_b, "coll": coll,
            "delta_c1": c1, "delta_c2": c2, "n_units": n,
            "slstm_corr_flops": sl_f}


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            out_dir: str | None = None, cfg=None, mesh=None,
            verbose: bool = True) -> dict:
    cfg = cfg or get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": _mesh_tag(multi_pod),
           "kind": shape.kind, "status": "ok"}
    if not cfg.supports_shape(shape_name):
        rec["status"] = "skipped"
        rec["reason"] = "enc-dec full attention: no 500k decode (DESIGN.md)"
        return _finish(rec, out_dir, verbose)

    mesh = mesh if mesh is not None else make_production_mesh(
        multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    try:
        lowered, state_shapes = build_lowered(cfg, shape, mesh)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(ma, k)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(ma, k)}
        except Exception as e:  # backend without memory analysis
            rec["memory_analysis"] = {"error": str(e)}

        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, list) else cost
        rec["flops_scanned_raw"] = float(cost.get("flops", -1))
        coll_raw = roofline.collective_bytes(compiled.as_text())
        rec["collective_bytes_scanned_raw"] = coll_raw

        # true whole-model cost via the delta method (single-pod only;
        # the multi-pod pass is the lowering proof, roofline is per-pod)
        if not multi_pod:
            meas = measure_cost(cfg, shape, mesh)
            rec["flops"] = meas["flops"]
            rec["bytes_accessed"] = meas["bytes"]
            rec["collective_bytes"] = meas["coll"]
            rec["delta_detail"] = {
                "c1": meas["delta_c1"], "c2": meas["delta_c2"],
                "n_units": meas["n_units"],
                "slstm_corr_flops": meas["slstm_corr_flops"]}
            rec["roofline"] = roofline.roofline_terms(
                {"flops": meas["flops"], "bytes accessed": meas["bytes"]},
                sum(meas["coll"].values()), n_chips)

        pshapes = state_shapes[0]
        n_params = roofline.count_params(pshapes)
        n_active = roofline.count_active_params(cfg, pshapes)
        tokens = shape.global_batch * (shape.seq_len
                                       if shape.kind != "decode" else 1)
        mf = roofline.model_flops(cfg, n_params, n_active, tokens,
                                  shape.kind)
        rec.update(n_params=n_params, n_active_params=n_active,
                   model_flops=mf, model_flops_per_chip=mf / n_chips)
        if rec.get("flops", 0) > 0:
            # compiled HLO flops are per-partition; compare like for like
            rec["useful_flops_ratio"] = (mf / n_chips) / rec["flops"]
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return _finish(rec, out_dir, verbose)


def _finish(rec: dict, out_dir: str | None, verbose: bool) -> dict:
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        r = rec.get("roofline", {})
        print(f"[dryrun] {rec['arch']:24s} {rec['shape']:12s} "
              f"{rec['mesh']:10s} {rec['status']:7s} "
              f"flops={rec.get('flops', 0):.3g} "
              f"dom={r.get('dominant', '-')}", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ARCH_NAMES if args.all or args.arch is None else [args.arch]
    shapes = list(INPUT_SHAPES) if args.all or args.shape is None \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_bad = 0
    for mp in meshes:
        for a in archs:
            for s in shapes:
                rec = run_one(a, s, multi_pod=mp, out_dir=args.out_dir)
                n_bad += rec["status"] == "error"
    if n_bad:
        raise SystemExit(f"{n_bad} dry-run combinations failed")


if __name__ == "__main__":
    main()
