"""Parameter / optimizer / input sharding rules.

Rules are (regex over the flattened param path) -> per-dimension logical
roles; a role maps to mesh axes only when the dimension size is divisible
by the axes' product (otherwise that dimension is replicated — e.g. MQA
kv projections with 1 head stay replicated rather than splitting a single
head's feature dim across the tensor-parallel axis).

Optimizer m/v (and any fp32 master state) additionally get the ZeRO-1
rule: the largest still-unsharded dimension divisible by the 'data' axis
is sharded over 'data', spreading optimizer memory across the pod.
"""

from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .mesh import batch_axes

# path-regex -> tuple of logical roles per dim (None = replicate)
# roles: 'tp' (model axis), 'ep' (experts over model axis)
_RULES: list[tuple[str, tuple]] = [
    (r"embed/table$", ("tp", None)),              # vocab sharded
    (r"unembed/w$", (None, "tp")),
    (r"(wq|wi|wg|up|wx)/w$", (None, "tp")),       # column parallel
    (r"(mlp|shared)/(wi|wg)$", (None, "tp")),     # MLP dicts hold raw arrays
    (r"(mlp|shared)/wo$", ("tp", None)),
    (r"(wk|wv)/w$", (None, "tp_heads")),          # only if kv heads divide
    (r"(wo|down|out_proj)/w$", ("tp", None)),     # row parallel
    (r"(wq|wk|wv|wi|wg|up|wx)/b$", ("tp",)),
    (r"moe/wi$", ("ep", None, None)),             # expert parallel
    (r"moe/wg$", ("ep", None, None)),
    (r"moe/wo$", ("ep", None, None)),
    (r"in_proj/w$", (None, "tp")),                # mamba2 fused projection
    (r"r$", ("tp", None, None)),                  # slstm recurrent (per head)
    (r"wif/w$", (None, None)),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return "/".join(parts)


def param_spec(path_str: str, shape, mesh, cfg=None) -> P:
    """PartitionSpec for one parameter."""
    m = mesh.shape.get("model", 1)
    for pat, roles in _RULES:
        if re.search(pat, path_str):
            spec = []
            # stacked-layer leading axes (scan stacking) are replicated;
            # roles apply to the trailing dims
            extra = len(shape) - len(roles)
            spec.extend([None] * extra)
            for dim, role in zip(shape[extra:], roles):
                if role in ("tp", "ep") and dim % m == 0:
                    spec.append("model")
                elif role == "tp_heads" and cfg is not None and \
                        cfg.n_kv_heads % m == 0 and dim % m == 0:
                    spec.append("model")
                else:
                    spec.append(None)
            return P(*spec)
    return P()  # norms, scalars, routers: replicated


def zero_extend(spec: P, shape, mesh) -> P:
    """ZeRO-1: shard the largest unsharded dim of optimizer state over
    'data' (and 'pod' when present, for the multi-pod mesh)."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    n = int(np.prod([mesh.shape[a] for a in axes]))
    parts = list(spec) + [None] * (len(shape) - len(spec))
    best, best_dim = -1, 0
    for i, (s, dim) in enumerate(zip(parts, shape)):
        if s is None and dim % n == 0 and dim > best_dim:
            best, best_dim = i, dim
    if best >= 0:
        parts[best] = tuple(axes) if len(axes) > 1 else axes[0]
    return P(*parts)


FSDP_THRESHOLD_BYTES = 4 << 30   # per-device params beyond this -> FSDP


def _tp_only_bytes_per_device(param_shapes, mesh, cfg) -> int:
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(param_shapes)[0]:
        spec = param_spec(_path_str(path), leaf.shape, mesh, cfg)
        denom = 1
        for s in spec:
            if s is None:
                continue
            for a in (s if isinstance(s, tuple) else (s,)):
                denom *= mesh.shape[a]
        total += leaf.size * leaf.dtype.itemsize // denom
    return total


def param_shardings(param_shapes, mesh, cfg=None, fsdp: str = "auto"):
    """Tree of NamedShardings matching a tree of ShapeDtypeStructs.

    fsdp: 'auto' enables ZeRO-3/FSDP-style extra sharding of every param
    over the data axes when the TP-only per-device footprint exceeds
    FSDP_THRESHOLD_BYTES (the 235B MoE and the deep granite stacks need
    it to fit 16 GB HBM); 'on'/'off' force the choice.
    """
    use_fsdp = (fsdp == "on" or
                (fsdp == "auto" and _tp_only_bytes_per_device(
                    param_shapes, mesh, cfg) > FSDP_THRESHOLD_BYTES))

    def one(path, leaf):
        spec = param_spec(_path_str(path), leaf.shape, mesh, cfg)
        if use_fsdp:
            spec = zero_extend(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, param_shapes)


def opt_shardings(opt_shapes, mesh, cfg=None):
    """Optimizer-state shardings: param rule + ZeRO-1 extension on m/v."""
    def one(path, leaf):
        ps = _path_str(path)
        inner = re.sub(r"^(m|v)/", "", ps)
        if ps.startswith(("m/", "v/")):
            spec = param_spec(inner, leaf.shape, mesh, cfg)
            spec = zero_extend(spec, leaf.shape, mesh)
        else:
            spec = P()  # step counter
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, opt_shapes)


def batch_spec(shape, mesh) -> P:
    """Shard the leading (batch) dim over the batch axes when divisible."""
    axes = batch_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in axes]))
    if shape and shape[0] % n == 0 and shape[0] > 0:
        lead = tuple(axes) if len(axes) > 1 else axes[0]
        return P(lead, *([None] * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def batch_shardings(batch_shapes, mesh):
    return jax.tree.map(
        lambda l: NamedSharding(mesh, batch_spec(l.shape, mesh)),
        batch_shapes)


def maybe(axis_or_axes, dim: int, mesh) -> object:
    """Return the axis spec entry if ``dim`` divides its device count."""
    axes = (axis_or_axes if isinstance(axis_or_axes, tuple)
            else (axis_or_axes,))
    n = int(np.prod([mesh.shape[a] for a in axes if a in mesh.axis_names]))
    if all(a in mesh.axis_names for a in axes) and dim % n == 0 and dim > 0:
        return axis_or_axes if isinstance(axis_or_axes, tuple) and \
            len(axis_or_axes) > 1 else axes[0]
    return None
