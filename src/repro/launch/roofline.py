"""Roofline-term extraction from compiled dry-run artifacts.

Three terms, per (arch x shape x mesh), in seconds (TPU v5e constants):

  compute    = HLO_FLOPs      / (chips * 197e12 FLOP/s bf16)
  memory     = HLO_bytes      / (chips * 819e9  B/s HBM)
  collective = collective_B   / (chips * 50e9   B/s per ICI link)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``; collective
bytes are parsed from the post-SPMD HLO text by summing operand sizes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops.  MODEL_FLOPS = 6*N*D (N = params, active params for MoE; D = tokens)
gives the useful-compute ratio.
"""

from __future__ import annotations

import re

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Bytes of one (possibly tuple) HLO shape string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum of operand bytes per collective op kind in an HLO module."""
    # first pass: result type of every named value
    types: dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        # rhs starts with the result type, e.g. "f32[8,128]{1,0} add(..."
        tm = re.match(r"^(\([^)]*\)|[\w]+\[[\d,]*\](?:\{[^}]*\})?)", rhs)
        if tm:
            types[name] = tm.group(1)

    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        _, rhs = m.groups()
        opm = re.search(r"\b(" + "|".join(_COLLECTIVES) + r")"
                        r"(?:-start|-done)?\(", rhs)
        if not opm:
            continue
        kind = opm.group(1)
        if "-done(" in rhs:
            continue  # avoid double count of async pairs
        # operands: %name tokens inside the call parens
        args = re.findall(r"%([\w.\-]+)", rhs.split("(", 1)[1])
        b = sum(_shape_bytes(types.get(a, "")) for a in args)
        if b == 0:
            # fall back to the result type (sync ops: result==operand size
            # for all-reduce / permute)
            tm = re.match(r"^(\([^)]*\)|[\w]+\[[\d,]*\](?:\{[^}]*\})?)", rhs)
            if tm:
                b = _shape_bytes(tm.group(1))
        out[kind] += b
    return out


def roofline_terms(cost: dict, coll_bytes: int, n_chips: int) -> dict:
    """The three terms in seconds + the dominant one.

    cost_analysis / the parsed HLO describe ONE SPMD partition (XLA
    compiles a single per-device program), so each term is simply the
    per-device quantity over the per-device peak; n_chips is kept for
    reference fields only.
    """
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = coll_bytes / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    terms["dominant"] = max(terms, key=lambda k: terms[k]).replace("_s", "")
    return terms


def model_flops(cfg, n_params: int, n_active_params: int, tokens: int,
                kind: str) -> float:
    """6*N*D (training) or 2*N*D (single forward / decode)."""
    n = n_active_params if cfg.family == "moe" else n_params
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens


def count_params(shapes_tree) -> int:
    import jax
    return sum(int(l.size) for l in jax.tree.leaves(shapes_tree))


def count_active_params(cfg, shapes_tree) -> int:
    """MoE: count routed experts at top_k/n_experts utilisation."""
    import jax
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes_tree)[0]:
        ps = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path)
        sz = int(leaf.size)
        if cfg.family == "moe" and re.search(r"moe/w[igo]$", ps):
            sz = int(sz * cfg.top_k / cfg.n_experts)
        total += sz
    return total
