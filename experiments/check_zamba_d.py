import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import dataclasses
from repro.configs import get_config, INPUT_SHAPES
from repro.launch.dryrun import build_lowered
from repro.launch.mesh import make_production_mesh

# D config: group-aware core (in code) + bf16 intra-chunk, seq_shard stays ON
cfg = dataclasses.replace(get_config("zamba2-2.7b"), ssm_compute_dtype="bf16")
mesh = make_production_mesh()
lowered, _ = build_lowered(cfg, INPUT_SHAPES["train_4k"], mesh)
ma = lowered.compile().memory_analysis()
print("D-config zamba2 train_4k: arg GB",
      round(ma.argument_size_in_bytes / 2**30, 1),
      "temp GB", round(ma.temp_size_in_bytes / 2**30, 1))
