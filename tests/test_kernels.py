"""Pallas kernel sweeps: shapes x dtypes, interpret=True vs ref.py oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.ops import HistSpec


def _hist1(bins, node, gh, *, n_nodes, nbins, backend):
    """Single-level histogram through the HistSpec API (the migration
    target of the deprecated ops.hist shim)."""
    spec = HistSpec(n_nodes=n_nodes, nbins=nbins, n_levels=1,
                    backend=backend)
    return ops.hist_levels(bins, node[None], gh, spec)[0]


# ---------------------------------------------------------------------------
# hist
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,f,nbins,nn", [
    (257, 3, 8, 1),          # odd rows -> padding path
    (1024, 7, 17, 5),        # odd bins
    (512, 1, 33, 8),         # single feature
    (2000, 11, 64, 16),      # node chunking kicks in
])
def test_hist_matches_ref(n, f, nbins, nn):
    key = jax.random.PRNGKey(n + f)
    bins = jax.random.randint(key, (n, f), 0, nbins)
    node = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, nn)
    gh = jax.random.normal(jax.random.fold_in(key, 2), (n, 2))
    r = ref.hist_ref(bins, node, gh, n_nodes=nn, nbins=nbins)
    p = _hist1(bins, node, gh, n_nodes=nn, nbins=nbins,
               backend="interpret")
    np.testing.assert_allclose(np.asarray(r), np.asarray(p),
                               rtol=1e-5, atol=1e-4)


def test_hist_masks_negative_nodes():
    bins = jnp.zeros((8, 2), jnp.int32)
    node = jnp.asarray([0, 0, -1, -1, 1, 1, -1, 0])
    gh = jnp.ones((8, 2))
    out = _hist1(bins, node, gh, n_nodes=2, nbins=4, backend="interpret")
    assert float(out.sum()) == pytest.approx(20.0)  # 5 rows x 2 feats x 2 stats
    r = ref.hist_ref(bins, node, gh, n_nodes=2, nbins=4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_hist_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    bins = jax.random.randint(key, (300, 4), 0, 9)
    node = jax.random.randint(key, (300,), 0, 3)
    gh = jax.random.normal(key, (300, 2)).astype(dtype)
    r = ref.hist_ref(bins, node, gh, n_nodes=3, nbins=9)
    p = _hist1(bins, node, gh, n_nodes=3, nbins=9, backend="interpret")
    np.testing.assert_allclose(np.asarray(r), np.asarray(p), rtol=2e-2,
                               atol=2e-2)


# ---------------------------------------------------------------------------
# split_gain
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nn,f,nbins", [(1, 2, 8), (4, 5, 16), (16, 3, 33)])
@pytest.mark.parametrize("l2,gamma,mcw", [(1.0, 0.0, 1e-6), (0.5, 0.3, 2.0)])
def test_split_gain_matches_ref(nn, f, nbins, l2, gamma, mcw):
    key = jax.random.PRNGKey(nn * f)
    hist = jnp.abs(jax.random.normal(key, (nn, f, nbins, 2)))
    g1, i1 = ref.split_gain_ref(hist, l2=l2, gamma=gamma,
                                min_child_weight=mcw)
    g2, i2 = ops.split_gain(hist, l2=l2, gamma=gamma, min_child_weight=mcw,
                            backend="interpret")
    finite = np.isfinite(np.asarray(g1))
    np.testing.assert_allclose(np.asarray(g1)[finite],
                               np.asarray(g2)[finite], rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(i1)[finite],
                                  np.asarray(i2)[finite])


def test_split_gain_never_picks_last_bin():
    hist = jnp.ones((2, 3, 8, 2))
    _, idx = ops.split_gain(hist, backend="interpret")
    assert int(jnp.max(idx)) < 7


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (1, 4, 4, 256, 64),      # MHA
    (2, 8, 2, 256, 64),      # GQA
    (1, 8, 1, 384, 128),     # MQA, odd-ish seq (384 = 3 x 128)
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 128),
                                           (False, 0)])
def test_flash_attention_matches_ref(b, hq, hkv, s, d, causal, window):
    key = jax.random.PRNGKey(b + s)
    q = jax.random.normal(key, (b, hq, s, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, hkv, s, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, hkv, s, d))
    a_ref = ref.attention_ref(q, k, v, causal=causal, window=window)
    a_pal = ops.flash_attention(q, k, v, causal=causal, window=window,
                                backend="interpret")
    np.testing.assert_allclose(np.asarray(a_ref), np.asarray(a_pal),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    key = jax.random.PRNGKey(9)
    q = jax.random.normal(key, (1, 4, 256, 64)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1),
                          (1, 2, 256, 64)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2),
                          (1, 2, 256, 64)).astype(jnp.bfloat16)
    a_ref = ref.attention_ref(q, k, v, causal=True)
    a_pal = ops.flash_attention(q, k, v, causal=True, backend="interpret")
    np.testing.assert_allclose(np.asarray(a_ref, np.float32),
                               np.asarray(a_pal, np.float32),
                               rtol=3e-2, atol=3e-2)
