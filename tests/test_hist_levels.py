"""Bit-exactness of the level-batched histogram behind the HistSpec API.

`ops.hist_levels` must reproduce a naive per-level `hist_ref` loop
EXACTLY (same f32 bits) on the 'ref' and 'packed' backends — the packed
complex64 scatter adds each bucket's rows in the same order, so no
re-association happens — and to tight tolerance on the Pallas interpret
path (one-hot matmul re-associates the row sum).  Shapes deliberately
include non-power-of-2 node counts, nbins=1, single-sample leaves, and
masked (-1) rows.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tree as tree_lib
from repro.kernels import ops, ref
from repro.kernels.ops import HistSpec


# (n, f, nbins, n_nodes, n_levels)
SHAPES = [
    (257, 3, 8, 3, 2),      # non-power-of-2 nodes, odd n
    (64, 2, 1, 4, 3),       # nbins=1: every row in bin 0
    (33, 5, 17, 32, 6),     # n_nodes ~ n: single-sample/empty leaves
    (1024, 7, 33, 16, 1),   # single level through the batched path
    (500, 4, 16, 5, 4),
]


def _case(n, f, nbins, n_nodes, L, seed=0, masked=True):
    rng = np.random.default_rng(seed)
    bins = jnp.asarray(rng.integers(0, nbins, (n, f)), jnp.int32)
    lo = -1 if masked else 0            # -1 rows must drop out entirely
    node = jnp.asarray(rng.integers(lo, n_nodes, (L, n)), jnp.int32)
    gh = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    return bins, node, gh


def _oracle(bins, node, gh, n_nodes, nbins):
    return jnp.stack([
        ref.hist_ref(bins, node[l], gh, n_nodes=n_nodes, nbins=nbins)
        for l in range(node.shape[0])])


@pytest.mark.parametrize("n,f,nbins,n_nodes,L", SHAPES)
@pytest.mark.parametrize("backend", ["ref", "packed"])
def test_hist_levels_bit_exact(n, f, nbins, n_nodes, L, backend):
    bins, node, gh = _case(n, f, nbins, n_nodes, L)
    spec = HistSpec(n_nodes=n_nodes, nbins=nbins, n_levels=L,
                    backend=backend)
    out = ops.hist_levels(bins, node, gh, spec)
    want = _oracle(bins, node, gh, n_nodes, nbins)
    assert out.shape == (L, n_nodes, f, nbins, 2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("n,f,nbins,n_nodes,L", SHAPES[:3])
def test_hist_levels_pallas_interpret(n, f, nbins, n_nodes, L):
    bins, node, gh = _case(n, f, nbins, n_nodes, L, seed=1)
    spec = HistSpec(n_nodes=n_nodes, nbins=nbins, n_levels=L,
                    backend="interpret")
    out = ops.hist_levels(bins, node, gh, spec)
    want = _oracle(bins, node, gh, n_nodes, nbins)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-6, atol=1e-5)


def test_hist_single_level_delegates():
    """ops.hist is the L=1 view of hist_levels (deprecated shim kept
    working, but it must warn)."""
    bins, node, gh = _case(300, 4, 9, 6, 1, seed=2)
    with pytest.warns(DeprecationWarning, match="ops.hist is deprecated"):
        one = ops.hist(bins, node[0], gh, n_nodes=6, nbins=9,
                       backend="packed")
    spec = HistSpec(n_nodes=6, nbins=9, n_levels=1, backend="packed")
    batched = ops.hist_levels(bins, node, gh, spec)
    np.testing.assert_array_equal(np.asarray(one), np.asarray(batched[0]))


def test_masked_rows_drop_out():
    """A -1 node id contributes nothing at that level, but the same row
    still counts at levels where it has a valid id."""
    bins, _, gh = _case(100, 2, 4, 3, 1, seed=3)
    rng = np.random.default_rng(3)
    node_ok = jnp.asarray(rng.integers(0, 3, (100,)), jnp.int32)
    node = jnp.stack([node_ok, node_ok.at[:50].set(-1)])
    spec = HistSpec(n_nodes=3, nbins=4, n_levels=2, backend="packed")
    out = ops.hist_levels(bins, node, gh, spec)
    np.testing.assert_array_equal(
        np.asarray(out[0]),
        np.asarray(ref.hist_ref(bins, node_ok, gh, n_nodes=3, nbins=4)))
    np.testing.assert_array_equal(
        np.asarray(out[1]),
        np.asarray(ref.hist_ref(bins, node[1], gh, n_nodes=3, nbins=4)))
    # level 1 lost exactly the first 50 rows' mass
    tot0 = float(out[0].sum())
    tot1 = float(out[1].sum())
    assert tot0 != tot1


# ---------------------------------------------------------------------------
# Child mode (subtraction growth): spec.subtract=True scatters only the
# LEFT-routed rows, keyed by parent id, into a half-width panel.  The
# grower reconstructs right children as parent - left; the invariant
# that makes that sound is parent == left + right per (feature, bin).
# ---------------------------------------------------------------------------

def _child_case(n, f, nbins, n_parents, L, seed=0, p_left=0.5):
    """Rows routed through L levels over n_parents parents: child id =
    2*parent + route per level (route 0 = LEFT), -1 = masked out."""
    rng = np.random.default_rng(seed)
    bins = jnp.asarray(rng.integers(0, nbins, (n, f)), jnp.int32)
    parent = rng.integers(-1, n_parents, (L, n))
    route = (rng.random((L, n)) >= p_left).astype(np.int64)
    child = np.where(parent >= 0, 2 * parent + route, -1)
    gh = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    return bins, jnp.asarray(child, jnp.int32), gh


@pytest.mark.parametrize("backend", ["ref", "packed"])
def test_child_mode_backends_bit_exact(backend):
    bins, child, gh = _child_case(300, 4, 9, 5, 3, seed=11)
    spec = HistSpec(n_nodes=5, nbins=9, n_levels=3, backend=backend,
                    subtract=True)
    out = ops.hist_levels(bins, child, gh, spec)
    want = ref.hist_levels_left_ref(bins, child, gh, n_nodes=5, nbins=9)
    assert out.shape == (3, 5, 4, 9, 2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_child_mode_pallas_interpret():
    bins, child, gh = _child_case(257, 3, 8, 4, 2, seed=12)
    spec = HistSpec(n_nodes=4, nbins=8, n_levels=2, backend="interpret",
                    subtract=True)
    out = ops.hist_levels(bins, child, gh, spec)
    want = ref.hist_levels_left_ref(bins, child, gh, n_nodes=4, nbins=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("p_left", [0.5, 0.97, 1.0])
def test_parent_equals_left_plus_right(p_left):
    """The subtraction invariant, including passthrough-heavy routing
    (p_left -> 1: nodes route everything LEFT, right children empty)."""
    n, f, nbins, P, L = 800, 3, 9, 4, 3
    bins, child, gh = _child_case(n, f, nbins, P, L, seed=7, p_left=p_left)
    left = ops.hist_levels(bins, child, gh,
                           HistSpec(n_nodes=P, nbins=nbins, n_levels=L,
                                    backend="packed", subtract=True))
    # direct child-frontier panel, split into (left, right) pairs
    full = ops.hist_levels(bins, child, gh,
                           HistSpec(n_nodes=2 * P, nbins=nbins, n_levels=L,
                                    backend="packed"))
    lr = full.reshape(L, P, 2, f, nbins, 2)
    parent_ids = jnp.where(child >= 0, child // 2, -1)
    parent = ops.hist_levels(bins, parent_ids, gh,
                             HistSpec(n_nodes=P, nbins=nbins, n_levels=L,
                                      backend="packed"))
    # the left panel is the direct left-child histogram, bit-for-bit
    np.testing.assert_array_equal(np.asarray(left), np.asarray(lr[:, :, 0]))
    # parent == left + right (tolerance: addition order differs)
    np.testing.assert_allclose(np.asarray(parent),
                               np.asarray(lr[:, :, 0] + lr[:, :, 1]),
                               rtol=1e-5, atol=1e-4)
    # the grower's reconstruction: parent - left == direct right child
    np.testing.assert_allclose(np.asarray(parent - left),
                               np.asarray(lr[:, :, 1]),
                               rtol=1e-5, atol=1e-4)


def test_build_tree_subtract_matches_direct():
    """Same tree out of subtraction growth and direct growth (the
    exactness contract at the tree level; raw hists differ in low bits)."""
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(600, 4)), jnp.float32)
    cand = jnp.sort(jnp.asarray(rng.normal(size=(4, 8)), jnp.float32), 1)
    from repro.core import binning
    bins = binning.bin_features(x, cand)
    gh = jnp.asarray(rng.normal(size=(600, 2)), jnp.float32)
    gh = gh.at[:, 1].set(jnp.abs(gh[:, 1]) + 0.1)
    for depth in (1, 2, 4):
        spec = HistSpec(n_nodes=2 ** max(depth - 1, 0), nbins=9,
                        n_levels=depth, backend="packed")
        direct = tree_lib.build_tree(bins, gh, cand, max_depth=depth,
                                     spec=spec)
        sub = tree_lib.build_tree(
            bins, gh, cand, max_depth=depth,
            spec=dataclasses.replace(spec, subtract=True))
        np.testing.assert_array_equal(np.asarray(direct.feature),
                                      np.asarray(sub.feature))
        np.testing.assert_array_equal(np.asarray(direct.split_bin),
                                      np.asarray(sub.split_bin))
        np.testing.assert_allclose(np.asarray(direct.threshold),
                                   np.asarray(sub.threshold), atol=1e-6)
        np.testing.assert_allclose(np.asarray(direct.leaf_value),
                                   np.asarray(sub.leaf_value), atol=1e-5)


def test_histspec_validation_and_views():
    with pytest.raises(ValueError):
        HistSpec(n_nodes=0, nbins=4)
    with pytest.raises(ValueError):
        HistSpec(n_nodes=2, nbins=0)
    with pytest.raises(ValueError):
        HistSpec(n_nodes=2, nbins=4, n_levels=0)
    with pytest.raises(ValueError):
        HistSpec(n_nodes=2, nbins=4, backend="cuda")
    with pytest.raises(ValueError):
        HistSpec(n_nodes=2, nbins=4, acc_dtype="bfloat16")
    spec = HistSpec(n_nodes=2, nbins=4, n_levels=3)
    assert spec.with_levels(1).n_levels == 1
    assert spec.with_levels(1).n_nodes == spec.n_nodes
    assert spec.resolved().backend in ("packed", "pallas")
    assert hash(spec) == hash(HistSpec(n_nodes=2, nbins=4, n_levels=3))
    cv = HistSpec(n_nodes=8, nbins=4).child_view()
    assert cv.n_nodes == 4 and cv.subtract is True
    assert HistSpec(n_nodes=1, nbins=4).child_view().n_nodes == 1


def test_hist_levels_shape_mismatch_raises():
    bins, node, gh = _case(50, 2, 4, 3, 2, seed=4)
    spec = HistSpec(n_nodes=3, nbins=4, n_levels=3, backend="packed")
    with pytest.raises(ValueError):
        ops.hist_levels(bins, node, gh, spec)      # node has 2 levels


def test_build_tree_spec_equals_kwargs():
    """build_tree(spec=...) is the same tree as the legacy kwargs path."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(400, 5)), jnp.float32)
    cand = jnp.sort(jnp.asarray(rng.normal(size=(5, 8)), jnp.float32), 1)
    from repro.core import binning
    bins = binning.bin_features(x, cand)
    gh = jnp.asarray(rng.normal(size=(400, 2)), jnp.float32)
    gh = gh.at[:, 1].set(jnp.abs(gh[:, 1]) + 0.1)

    legacy = tree_lib.build_tree(bins, gh, cand, max_depth=4, nbins=9,
                                 backend="packed")
    spec = HistSpec(n_nodes=8, nbins=9, n_levels=4, backend="packed")
    new = tree_lib.build_tree(bins, gh, cand, max_depth=4, spec=spec)
    for a, b in zip(legacy, new):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    with pytest.raises(ValueError):     # conflicting nbins
        tree_lib.build_tree(bins, gh, cand, max_depth=4, nbins=5, spec=spec)
    with pytest.raises(ValueError):     # frontier wider than spec
        tree_lib.build_tree(bins, gh, cand, max_depth=5, spec=spec)
    with pytest.raises(TypeError):      # neither spec nor nbins
        tree_lib.build_tree(bins, gh, cand, max_depth=4)
