"""Bit-exactness of the level-batched histogram behind the HistSpec API.

`ops.hist_levels` must reproduce a naive per-level `hist_ref` loop
EXACTLY (same f32 bits) on the 'ref' and 'packed' backends — the packed
complex64 scatter adds each bucket's rows in the same order, so no
re-association happens — and to tight tolerance on the Pallas interpret
path (one-hot matmul re-associates the row sum).  Shapes deliberately
include non-power-of-2 node counts, nbins=1, single-sample leaves, and
masked (-1) rows.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tree as tree_lib
from repro.kernels import ops, ref
from repro.kernels.ops import HistSpec


# (n, f, nbins, n_nodes, n_levels)
SHAPES = [
    (257, 3, 8, 3, 2),      # non-power-of-2 nodes, odd n
    (64, 2, 1, 4, 3),       # nbins=1: every row in bin 0
    (33, 5, 17, 32, 6),     # n_nodes ~ n: single-sample/empty leaves
    (1024, 7, 33, 16, 1),   # single level through the batched path
    (500, 4, 16, 5, 4),
]


def _case(n, f, nbins, n_nodes, L, seed=0, masked=True):
    rng = np.random.default_rng(seed)
    bins = jnp.asarray(rng.integers(0, nbins, (n, f)), jnp.int32)
    lo = -1 if masked else 0            # -1 rows must drop out entirely
    node = jnp.asarray(rng.integers(lo, n_nodes, (L, n)), jnp.int32)
    gh = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    return bins, node, gh


def _oracle(bins, node, gh, n_nodes, nbins):
    return jnp.stack([
        ref.hist_ref(bins, node[l], gh, n_nodes=n_nodes, nbins=nbins)
        for l in range(node.shape[0])])


@pytest.mark.parametrize("n,f,nbins,n_nodes,L", SHAPES)
@pytest.mark.parametrize("backend", ["ref", "packed"])
def test_hist_levels_bit_exact(n, f, nbins, n_nodes, L, backend):
    bins, node, gh = _case(n, f, nbins, n_nodes, L)
    spec = HistSpec(n_nodes=n_nodes, nbins=nbins, n_levels=L,
                    backend=backend)
    out = ops.hist_levels(bins, node, gh, spec)
    want = _oracle(bins, node, gh, n_nodes, nbins)
    assert out.shape == (L, n_nodes, f, nbins, 2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("n,f,nbins,n_nodes,L", SHAPES[:3])
def test_hist_levels_pallas_interpret(n, f, nbins, n_nodes, L):
    bins, node, gh = _case(n, f, nbins, n_nodes, L, seed=1)
    spec = HistSpec(n_nodes=n_nodes, nbins=nbins, n_levels=L,
                    backend="interpret")
    out = ops.hist_levels(bins, node, gh, spec)
    want = _oracle(bins, node, gh, n_nodes, nbins)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-6, atol=1e-5)


def test_hist_single_level_delegates():
    """ops.hist is the L=1 view of hist_levels (old API kept working)."""
    bins, node, gh = _case(300, 4, 9, 6, 1, seed=2)
    one = ops.hist(bins, node[0], gh, n_nodes=6, nbins=9, backend="packed")
    spec = HistSpec(n_nodes=6, nbins=9, n_levels=1, backend="packed")
    batched = ops.hist_levels(bins, node, gh, spec)
    np.testing.assert_array_equal(np.asarray(one), np.asarray(batched[0]))


def test_masked_rows_drop_out():
    """A -1 node id contributes nothing at that level, but the same row
    still counts at levels where it has a valid id."""
    bins, _, gh = _case(100, 2, 4, 3, 1, seed=3)
    rng = np.random.default_rng(3)
    node_ok = jnp.asarray(rng.integers(0, 3, (100,)), jnp.int32)
    node = jnp.stack([node_ok, node_ok.at[:50].set(-1)])
    spec = HistSpec(n_nodes=3, nbins=4, n_levels=2, backend="packed")
    out = ops.hist_levels(bins, node, gh, spec)
    np.testing.assert_array_equal(
        np.asarray(out[0]),
        np.asarray(ref.hist_ref(bins, node_ok, gh, n_nodes=3, nbins=4)))
    np.testing.assert_array_equal(
        np.asarray(out[1]),
        np.asarray(ref.hist_ref(bins, node[1], gh, n_nodes=3, nbins=4)))
    # level 1 lost exactly the first 50 rows' mass
    tot0 = float(out[0].sum())
    tot1 = float(out[1].sum())
    assert tot0 != tot1


def test_histspec_validation_and_views():
    with pytest.raises(ValueError):
        HistSpec(n_nodes=0, nbins=4)
    with pytest.raises(ValueError):
        HistSpec(n_nodes=2, nbins=0)
    with pytest.raises(ValueError):
        HistSpec(n_nodes=2, nbins=4, n_levels=0)
    with pytest.raises(ValueError):
        HistSpec(n_nodes=2, nbins=4, backend="cuda")
    with pytest.raises(ValueError):
        HistSpec(n_nodes=2, nbins=4, acc_dtype="bfloat16")
    spec = HistSpec(n_nodes=2, nbins=4, n_levels=3)
    assert spec.with_levels(1).n_levels == 1
    assert spec.with_levels(1).n_nodes == spec.n_nodes
    assert spec.resolved().backend in ("packed", "pallas")
    assert hash(spec) == hash(HistSpec(n_nodes=2, nbins=4, n_levels=3))


def test_hist_levels_shape_mismatch_raises():
    bins, node, gh = _case(50, 2, 4, 3, 2, seed=4)
    spec = HistSpec(n_nodes=3, nbins=4, n_levels=3, backend="packed")
    with pytest.raises(ValueError):
        ops.hist_levels(bins, node, gh, spec)      # node has 2 levels


def test_build_tree_spec_equals_kwargs():
    """build_tree(spec=...) is the same tree as the legacy kwargs path."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(400, 5)), jnp.float32)
    cand = jnp.sort(jnp.asarray(rng.normal(size=(5, 8)), jnp.float32), 1)
    from repro.core import binning
    bins = binning.bin_features(x, cand)
    gh = jnp.asarray(rng.normal(size=(400, 2)), jnp.float32)
    gh = gh.at[:, 1].set(jnp.abs(gh[:, 1]) + 0.1)

    legacy = tree_lib.build_tree(bins, gh, cand, max_depth=4, nbins=9,
                                 backend="packed")
    spec = HistSpec(n_nodes=8, nbins=9, n_levels=4, backend="packed")
    new = tree_lib.build_tree(bins, gh, cand, max_depth=4, spec=spec)
    for a, b in zip(legacy, new):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    with pytest.raises(ValueError):     # conflicting nbins
        tree_lib.build_tree(bins, gh, cand, max_depth=4, nbins=5, spec=spec)
    with pytest.raises(ValueError):     # frontier wider than spec
        tree_lib.build_tree(bins, gh, cand, max_depth=5, spec=spec)
    with pytest.raises(TypeError):      # neither spec nor nbins
        tree_lib.build_tree(bins, gh, cand, max_depth=4)
