"""MoE layer: dispatch-mode equivalence, grouping, capacity semantics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe


def _cfg(**kw):
    return dataclasses.replace(get_config("deepseek-moe-16b", smoke=True),
                               **kw)


def _run(cfg, seed=0, b=2, s=32):
    key = jax.random.PRNGKey(seed)
    p = moe.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1),
                          (b, s, cfg.d_model), jnp.float32) * 0.3
    return moe.moe_layer(p, cfg, x)


def test_sort_equals_onehot_dispatch():
    """The §Perf sort-based dispatch must agree with the one-hot baseline
    whenever no tokens are dropped (generous capacity)."""
    c1 = _cfg(moe_dispatch="onehot", capacity_factor=8.0)
    c2 = _cfg(moe_dispatch="sort", capacity_factor=8.0)
    y1, a1 = _run(c1)
    y2, a2 = _run(c2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)
    assert float(a1) == pytest.approx(float(a2), rel=1e-5)


def test_grouping_invariance_with_headroom():
    """With ample capacity, dispatching in G groups == 1 group."""
    y1, _ = _run(_cfg(moe_groups=1, capacity_factor=8.0))
    y2, _ = _run(_cfg(moe_groups=4, capacity_factor=8.0))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)


def test_capacity_drops_tokens():
    """Tiny capacity must drop tokens (outputs differ from roomy run) but
    stay finite."""
    y_room, _ = _run(_cfg(capacity_factor=8.0))
    y_tight, _ = _run(_cfg(capacity_factor=0.25))
    assert bool(jnp.all(jnp.isfinite(y_tight)))
    assert float(jnp.abs(y_room - y_tight).max()) > 1e-6


def test_aux_loss_positive_and_order_one():
    _, aux = _run(_cfg())
    assert 0.0 < float(aux) < 1.0


def test_shared_experts_contribute():
    c_with = _cfg(n_shared_experts=1)
    c_wo = dataclasses.replace(c_with, n_shared_experts=0)
    key = jax.random.PRNGKey(0)
    p = moe.init_moe(key, c_with)
    x = jax.random.normal(key, (1, 8, c_with.d_model)) * 0.3
    y1, _ = moe.moe_layer(p, c_with, x)
    p2 = {k: v for k, v in p.items() if k != "shared"}
    y2, _ = moe.moe_layer(p2, c_wo, x)
    assert float(jnp.abs(y1 - y2).max()) > 1e-6


def test_moe_grad_flows_to_router():
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    p = moe.init_moe(key, cfg)
    x = jax.random.normal(key, (1, 16, cfg.d_model)) * 0.3

    def loss(p):
        y, aux = moe.moe_layer(p, cfg, x)
        return jnp.sum(y ** 2) + aux
    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]["w"]).max()) > 0.0
    assert float(jnp.abs(g["wi"]).max()) > 0.0
