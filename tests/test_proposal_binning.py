"""Proposal strategies + binning consistency invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # dev dependency; see requirements-dev.txt — only the property
    # test needs it, the deterministic invariants below always run
    from hypothesis import given, settings, strategies as st
except ImportError:                                # pragma: no cover
    given = None

from repro.core import binning, proposal


@pytest.mark.parametrize("strategy", ["random", "weighted_quantile",
                                      "uniform_range", "exact",
                                      "gk_quantile"])
def test_propose_shapes_and_sorted(strategy):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (500, 4))
    c = proposal.propose(strategy, x, 8, key=key,
                         hess=jnp.ones(500))
    assert c.shape == (4, 8)
    assert bool(jnp.all(jnp.diff(c, axis=1) >= 0))


def _check_threshold_consistency(seed):
    """The core invariant linking train (bin space) and inference (raw):
    bin_id(x) <= s  <=>  x <= candidates[s]."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(200, 1)).astype(np.float32)
    cand = np.sort(rng.normal(size=(1, 8)).astype(np.float32), axis=1)
    bins = np.asarray(binning.bin_features(jnp.asarray(x), jnp.asarray(cand)))
    for s in range(8):
        left_by_bin = bins[:, 0] <= s
        left_by_val = x[:, 0] <= cand[0, s]
        np.testing.assert_array_equal(left_by_bin, left_by_val)


def test_binning_threshold_consistency_fixed_seeds():
    for seed in (0, 1, 2):
        _check_threshold_consistency(seed)


if given is not None:
    @given(seed=st.integers(0, 1000))
    @settings(max_examples=20, deadline=None)
    def test_binning_threshold_consistency(seed):
        _check_threshold_consistency(seed)


def test_bin_range():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (300, 3))
    c = proposal.propose("random", x, 8, key=key)
    b = binning.bin_features(x, c)
    assert int(b.min()) >= 0 and int(b.max()) <= 8   # nbins = k+1


def test_resample_gathered_deterministic():
    """Algorithm 1's shared-key resample: every worker computes the SAME
    candidate set from the gathered pool (no broadcast needed)."""
    key = jax.random.PRNGKey(3)
    pool = jax.random.normal(key, (4, 5, 8))     # (workers, f, b)
    c1 = proposal.resample_gathered(key, pool, 8)
    c2 = proposal.resample_gathered(key, pool, 8)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    assert c1.shape == (5, 8)


def test_exact_covers_unique_values():
    x = np.array([[0.0], [1.0], [2.0], [1.0]], dtype=np.float32)
    c = proposal.exact_candidates(x, 4)
    assert set(np.unique(c[0])) == {0.0, 1.0, 2.0}


@pytest.mark.parametrize("k", [8, 65])  # dense (k<=64) and searchsorted
def test_nan_rows_bin_to_last_bin_on_both_paths(k):
    """NaN features go to bin k on BOTH binning paths, so a NaN row
    never splits left of any finite threshold regardless of k."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(32, 3)).astype(np.float32)
    x[5, 0] = np.nan
    x[17, 2] = np.nan
    cand = np.sort(rng.normal(size=(3, k)).astype(np.float32), axis=1)
    bins = np.asarray(binning.bin_features(jnp.asarray(x),
                                           jnp.asarray(cand)))
    assert bins[5, 0] == k and bins[17, 2] == k
    # finite entries are untouched and in range
    finite = ~np.isnan(x)
    assert (bins[finite] >= 0).all() and (bins[finite] <= k).all()
    ss = np.stack([np.searchsorted(cand[j], x[:, j], side="left")
                   for j in range(3)], axis=1)
    np.testing.assert_array_equal(bins, ss.astype(np.int32))


@pytest.mark.parametrize("fn", [proposal.gk_quantile_candidates,
                                proposal.exact_candidates])
def test_degenerate_features_do_not_crash(fn):
    """Constant and empty feature columns yield zero-length candidate
    arrays; the proposers must pad instead of raising (np.pad with
    mode='edge' crashes on an empty array)."""
    const = np.full((50, 2), 3.5, dtype=np.float32)
    c = fn(const, 4)
    assert c.shape == (2, 4)
    assert np.isfinite(c).all()

    empty = np.empty((0, 3), dtype=np.float32)
    c = fn(empty, 4)
    assert c.shape == (3, 4)
    np.testing.assert_array_equal(c, 0.0)
