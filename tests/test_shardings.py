"""Sharding-rule unit tests (no devices needed: specs are pure data)."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.shardings import (batch_spec, param_spec, zero_extend)


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}
    size = 256


class FakePodMesh:
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 16, "model": 16}
    size = 512


MESH = FakeMesh()


def test_column_parallel():
    assert param_spec("layers/attn/wq/w", (40, 4096, 4096), MESH) == \
        P(None, None, "model")
    assert param_spec("layers/mlp/wi", (40, 4096, 13696), MESH) == \
        P(None, None, "model")


def test_row_parallel():
    assert param_spec("layers/attn/wo/w", (40, 4096, 4096), MESH) == \
        P(None, "model", None)
    assert param_spec("layers/mlp/wo", (40, 13696, 4096), MESH) == \
        P(None, "model", None)


def test_mqa_kv_replicated():
    cfg = get_config("granite-34b")          # kv = 1
    assert param_spec("layers/attn/wk/w", (88, 6144, 128), MESH, cfg) == \
        P(None, None, None)


def test_gqa_kv_sharded_when_divisible():
    cfg = get_config("deepseek-moe-16b")     # kv = 16
    assert param_spec("layers/attn/wk/w", (28, 2048, 2048), MESH, cfg) == \
        P(None, None, "model")


def test_experts_sharded():
    assert param_spec("layers/moe/wi", (94, 128, 4096, 1536), MESH) == \
        P(None, "model", None, None)


def test_norms_replicated():
    assert param_spec("layers/ln1/scale", (40, 4096), MESH) == P()


def test_vocab_sharded_embed():
    assert param_spec("embed/table", (151552, 4096), MESH) == \
        P("model", None)


def test_indivisible_dims_fall_back():
    # vocab 151655 (internvl) is not divisible by 16 -> replicate
    assert param_spec("embed/table", (151655, 896), MESH) == P(None, None)


def test_zero_extend_picks_largest_free_dim():
    spec = zero_extend(P(None, None, "model"), (40, 4096, 13696), MESH)
    assert spec == P(None, "data", "model")
    # fully sharded already -> unchanged
    spec2 = zero_extend(P("data", "model"), (160, 4096), MESH)
    assert spec2 == P("data", "model")


def test_zero_extend_multipod_uses_both_axes():
    spec = zero_extend(P(None, None), (64, 4096), FakePodMesh())
    assert spec == P(None, ("pod", "data"))


def test_batch_spec_divisible():
    assert batch_spec((256, 4096), MESH) == P("data", None)
    assert batch_spec((256, 4096), FakePodMesh()) == P(("pod", "data"), None)
    # batch 1 (long_500k) cannot shard
    assert batch_spec((1, 4096), MESH) == P(None, None)
