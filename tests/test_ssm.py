"""SSM invariants: chunked-parallel forms == sequential recurrences."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ssm


def _seq_reference(q, k, v, ld):
    """Token-by-token recurrence using decay_attention_step."""
    b, s, h, n = q.shape
    p = v.shape[-1]
    state = jnp.zeros((b, h, n, p), jnp.float32)
    ys = []
    for t in range(s):
        y, state = ssm.decay_attention_step(q[:, t], k[:, t], v[:, t],
                                            ld[:, t], state)
        ys.append(y)
    return jnp.stack(ys, 1), state


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_chunked_decay_attention_matches_sequential(chunk):
    key = jax.random.PRNGKey(0)
    b, s, h, n, p = 2, 32, 3, 8, 5
    q = jax.random.normal(key, (b, s, h, n))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, n)) * 0.3
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, p))
    ld = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 3), (b, s, h)))
    y_seq, st_seq = _seq_reference(q, k, v, ld)
    y_chk, st_chk = ssm.chunked_decay_attention(q, k, v, ld, chunk)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_chk),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_seq), np.asarray(st_chk),
                               rtol=1e-4, atol=1e-4)


def test_chunked_scan_vs_unrolled():
    key = jax.random.PRNGKey(5)
    b, s, h, n, p = 1, 64, 2, 4, 4
    args = (jax.random.normal(key, (b, s, h, n)),
            jax.random.normal(key, (b, s, h, n)),
            jax.random.normal(key, (b, s, h, p)),
            -jnp.abs(jax.random.normal(key, (b, s, h))))
    y1, s1 = ssm.chunked_decay_attention(*args, 16, scan_chunks=True)
    y2, s2 = ssm.chunked_decay_attention(*args, 16, scan_chunks=False)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)


def test_mamba2_layer_matches_steps():
    """Chunked SSD prefill == token-by-token decode recurrence."""
    cfg = get_config("zamba2-2.7b", smoke=True)
    key = jax.random.PRNGKey(1)
    p = ssm.init_mamba2(key, cfg)
    b, s = 2, 32
    x = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32) * 0.1
    y_full, st_full = ssm.mamba2_layer(p, cfg, x)
    state = jnp.zeros(ssm.mamba2_state_shape(cfg, b), jnp.float32)
    ys = []
    for t in range(s):
        y, state = ssm.mamba2_step(p, cfg, x[:, t:t + 1], state)
        ys.append(y)
    y_step = jnp.concatenate(ys, 1)
    np.testing.assert_allclose(np.asarray(y_full, np.float32),
                               np.asarray(y_step, np.float32),
                               rtol=2e-2, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st_full), np.asarray(state),
                               rtol=2e-2, atol=2e-3)


def test_mlstm_layer_matches_steps():
    cfg = get_config("xlstm-125m", smoke=True)
    key = jax.random.PRNGKey(2)
    p = ssm.init_mlstm(key, cfg)
    b, s = 2, 32
    x = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32) * 0.1
    y_full, st_full = ssm.mlstm_layer(p, cfg, x)
    state = jnp.zeros(ssm.mlstm_state_shape(cfg, b), jnp.float32)
    ys = []
    for t in range(s):
        y, state = ssm.mlstm_step(p, cfg, x[:, t:t + 1], state)
        ys.append(y)
    y_step = jnp.concatenate(ys, 1)
    np.testing.assert_allclose(np.asarray(y_full, np.float32),
                               np.asarray(y_step, np.float32),
                               rtol=2e-2, atol=2e-3)


def test_slstm_step_continues_sequence():
    """Running sLSTM over [a;b] == running over a, then b from a's state."""
    cfg = get_config("xlstm-125m", smoke=True)
    key = jax.random.PRNGKey(3)
    p = ssm.init_slstm(key, cfg)
    b = 2
    x = jax.random.normal(key, (b, 16, cfg.d_model), jnp.float32) * 0.1
    y_all, _ = ssm.slstm_layer(p, cfg, x)
    y_a, st = ssm.slstm_layer(p, cfg, x[:, :8])
    y_b, _ = ssm.slstm_layer(p, cfg, x[:, 8:], st)
    np.testing.assert_allclose(np.asarray(y_all, np.float32),
                               np.asarray(jnp.concatenate([y_a, y_b], 1),
                                          np.float32),
                               rtol=1e-4, atol=1e-5)


def test_decay_preserves_stability():
    """With decays <= 1 and bounded inputs the state stays bounded."""
    key = jax.random.PRNGKey(4)
    b, s, h, n, p = 1, 512, 2, 4, 4
    q = jax.random.normal(key, (b, s, h, n))
    k = jax.random.normal(key, (b, s, h, n))
    v = jax.random.normal(key, (b, s, h, p))
    ld = jnp.full((b, s, h), -0.05)
    y, st = ssm.chunked_decay_attention(q, k, v, ld, 64)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(jnp.abs(st).max()) < 1e4
