"""Retrace behavior of the scanned boosting trainer and the batched
inference engine.

The whole point of the lax.scan round runner is that trace/compile cost
is O(1) in n_trees: the round step's Python body executes once per
trace of the surrounding jit, so ``boosting.round_trace_count()`` is a
direct lowering count of the hot loop.  Doubling n_trees must not
increase it, and refitting with unchanged (config, shapes) must hit the
jit cache and add zero traces.

Where the installed JAX exposes ``jax.monitoring`` event listeners, the
same invariant is cross-checked against XLA compile events.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import boosting, predict as predict_lib
from repro.launch.serve_gbdt import synthetic_gbdt


def _toy(n=1000, f=4, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, f))
    w = jax.random.normal(jax.random.fold_in(key, 1), (f,))
    y = (x @ w > 0).astype(jnp.float32)
    return x, y


def _fit_traces(x, y, cfg):
    before = boosting.round_trace_count()
    boosting.fit(x, y, cfg, jax.random.PRNGKey(0))
    return boosting.round_trace_count() - before


def test_doubling_n_trees_does_not_retrace_more():
    x, y = _toy()
    base = dict(max_depth=4, n_candidates=16)
    t_small = _fit_traces(x, y, boosting.GBDTConfig(n_trees=4, **base))
    t_double = _fit_traces(x, y, boosting.GBDTConfig(n_trees=8, **base))
    t_quad = _fit_traces(x, y, boosting.GBDTConfig(n_trees=16, **base))
    assert t_small == 1, t_small          # one trace of the round step
    assert t_double == t_small            # O(1) in n_trees, not O(n_trees)
    assert t_quad == t_small


def test_telemetry_round_step_traces_o1():
    """The ROADMAP rule for new jitted entry points: the telemetry-
    enabled round step (TrainReport rows as extra scan outputs) must
    keep the O(1)-in-n_trees compile property of the plain one."""
    x, y = _toy(seed=3)
    base = dict(max_depth=4, n_candidates=16, telemetry=True)
    t_small = _fit_traces(x, y, boosting.GBDTConfig(n_trees=4, **base))
    t_double = _fit_traces(x, y, boosting.GBDTConfig(n_trees=8, **base))
    t_quad = _fit_traces(x, y, boosting.GBDTConfig(n_trees=16, **base))
    assert t_small == 1, t_small
    assert t_double == t_small
    assert t_quad == t_small
    # refit with unchanged config: jit cache hit, zero new traces
    assert _fit_traces(x, y, boosting.GBDTConfig(n_trees=4, **base)) == 0


def test_subtract_round_step_traces_o1():
    """Subtraction growth swaps the level scan's body (child-mode
    scatter + panel carry) — still one round-step trace regardless of
    n_trees, and a refit hits the jit cache."""
    x, y = _toy(seed=5)
    base = dict(max_depth=4, n_candidates=16, subtract=True,
                telemetry=True)
    t_small = _fit_traces(x, y, boosting.GBDTConfig(n_trees=4, **base))
    t_double = _fit_traces(x, y, boosting.GBDTConfig(n_trees=8, **base))
    assert t_small == 1, t_small
    assert t_double == t_small
    assert _fit_traces(x, y, boosting.GBDTConfig(n_trees=4, **base)) == 0


def test_refit_same_config_hits_jit_cache():
    x, y = _toy(seed=1)
    cfg = boosting.GBDTConfig(n_trees=4, max_depth=4, n_candidates=16)
    _fit_traces(x, y, cfg)                # warm (may or may not be cached)
    assert _fit_traces(x, y, cfg) == 0    # second fit: zero new traces
    # a different key is NOT a retrace either (keys are traced values)
    before = boosting.round_trace_count()
    boosting.fit(x, y, cfg, jax.random.PRNGKey(99))
    assert boosting.round_trace_count() - before == 0


def test_traversal_traces_o1_in_n_trees():
    """Inference mirrors the trainer's contract: the batched traversal's
    chunk step traces at most once per fresh compiled predict no matter
    how many trees the forest holds (the chunk axis is a lax.scan), and
    a repeat call with unchanged (shapes, spec) adds zero traces."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(300, 5)).astype(np.float32))

    def fresh_traces(n_trees):
        model = synthetic_gbdt(n_trees=n_trees, max_depth=3, n_features=5,
                               n_candidates=8, seed=n_trees)
        before = predict_lib.traverse_trace_count()
        predict_lib.forest_predict(model.forest, x, max_depth=3,
                                   tree_chunk=4)
        fresh = predict_lib.traverse_trace_count() - before
        before = predict_lib.traverse_trace_count()
        predict_lib.forest_predict(model.forest, x, max_depth=3,
                                   tree_chunk=4)
        repeat = predict_lib.traverse_trace_count() - before
        return fresh, repeat

    f8, r8 = fresh_traces(8)
    f32, r32 = fresh_traces(32)
    assert f8 <= 1 and f32 <= 1, (f8, f32)   # O(1) in n_trees
    assert r8 == 0 and r32 == 0, (r8, r32)   # jit cache hit on repeat


def test_compile_events_constant_in_n_trees():
    """Cross-check via jax.monitoring where available: the number of XLA
    backend compiles triggered by a fit does not grow with n_trees."""
    if not hasattr(jax, "monitoring") or \
            not hasattr(jax.monitoring, "register_event_listener"):
        pytest.skip("jax.monitoring event listeners unavailable")
    events = []
    jax.monitoring.register_event_listener(
        lambda name, **kw: events.append(name))

    def compiles_for(n_trees):
        x, y = _toy(n=512, f=3, seed=2 + n_trees)   # fresh shapes per call
        cfg = boosting.GBDTConfig(n_trees=n_trees, max_depth=3,
                                  n_candidates=8)
        start = len(events)
        boosting.fit(x, y, cfg, jax.random.PRNGKey(0))
        return sum("compile" in e for e in events[start:])

    c4 = compiles_for(4)
    c8 = compiles_for(8)
    assert c8 <= c4, (c4, c8)             # doubling rounds: no extra compiles
