"""The stable top-level `repro` API and the deprecation story.

Pins: (1) the `repro.__init__` export surface, (2) the unified
`proposal.propose` dispatcher (jit-context auto-detection, host-only
strategies refusing to trace, deprecated `propose_traced` alias), and
(3) `GBDTModel.predict(output=...)` with the deprecated
`predict_margin` alias.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import boosting, proposal


def _toy(n=600, f=4, seed=0, objective="logistic"):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, f))
    w = jax.random.normal(jax.random.fold_in(key, 1), (f,))
    if objective == "logistic":
        y = (x @ w > 0).astype(jnp.float32)
    else:
        y = (x @ w).astype(jnp.float32)
    return x, y


# ---------------------------------------------------------------------------
# Export surface.
# ---------------------------------------------------------------------------

def test_top_level_exports():
    required = {"GBDTConfig", "fit", "fit_reference", "fit_distributed",
                "Forest", "HistSpec"}
    assert required <= set(repro.__all__)
    for name in repro.__all__:
        assert getattr(repro, name) is not None
    # the re-exports ARE the canonical objects, not copies
    assert repro.GBDTConfig is boosting.GBDTConfig
    assert repro.fit is boosting.fit


def test_top_level_fit_roundtrip():
    x, y = _toy()
    cfg = repro.GBDTConfig(n_trees=3, max_depth=3, n_candidates=8)
    m = repro.fit(x, y, cfg, jax.random.PRNGKey(0))
    assert isinstance(m.forest, repro.Forest)
    assert 0.5 <= repro.accuracy(m, x, y) <= 1.0


# ---------------------------------------------------------------------------
# Unified propose dispatcher.
# ---------------------------------------------------------------------------

def test_propose_host_matches_strategies():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(100, 3)),
                    jnp.float32)
    key = jax.random.PRNGKey(1)
    np.testing.assert_array_equal(
        np.asarray(proposal.propose("random", x, 5, key=key)),
        np.asarray(proposal.random_candidates(key, x, 5)))
    np.testing.assert_array_equal(
        np.asarray(proposal.propose("exact", x, 5)),
        np.asarray(proposal.exact_candidates(np.asarray(x), 5)))


def test_propose_auto_detects_jit_context():
    x = jnp.asarray(np.random.default_rng(1).normal(size=(64, 2)),
                    jnp.float32)

    @jax.jit
    def traced(x, key):
        return proposal.propose("random", x, 4, key=key)

    key = jax.random.PRNGKey(2)
    np.testing.assert_array_equal(
        np.asarray(traced(x, key)),
        np.asarray(proposal.propose("random", x, 4, key=key)))


@pytest.mark.parametrize("strategy", ["gk_quantile", "exact"])
def test_propose_host_only_refuses_to_trace(strategy):
    x = jnp.ones((16, 2), jnp.float32)

    @jax.jit
    def traced(x):
        return proposal.propose(strategy, x, 3)

    with pytest.raises(ValueError, match="host-only"):
        traced(x)
    # forcing traced=True outside jit hits the same guard
    with pytest.raises(ValueError, match="host-only"):
        proposal.propose(strategy, x, 3, traced=True)


def test_propose_traced_alias_warns_and_matches():
    x = jnp.asarray(np.random.default_rng(2).normal(size=(50, 2)),
                    jnp.float32)
    key = jax.random.PRNGKey(3)
    hess = jnp.ones((50,), jnp.float32)
    with pytest.warns(DeprecationWarning, match="propose_traced"):
        old = proposal.propose_traced("weighted_quantile", x, 4, key, hess)
    new = proposal.propose("weighted_quantile", x, 4, key=key, hess=hess)
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))


def test_propose_weighted_quantile_defaults_hess_to_ones():
    x = jnp.asarray(np.random.default_rng(3).normal(size=(80, 2)),
                    jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(proposal.propose("weighted_quantile", x, 4)),
        np.asarray(proposal.propose("weighted_quantile", x, 4,
                                    hess=jnp.ones((80,), jnp.float32))))


# ---------------------------------------------------------------------------
# GBDTModel.predict(output=...).
# ---------------------------------------------------------------------------

def test_predict_outputs_logistic():
    x, y = _toy(seed=4)
    cfg = repro.GBDTConfig(n_trees=3, max_depth=3, n_candidates=8)
    m = repro.fit(x, y, cfg, jax.random.PRNGKey(0))
    margin = m.predict(x, output="margin")
    proba = m.predict(x, output="proba")
    label = m.predict(x, output="label")
    np.testing.assert_allclose(np.asarray(proba),
                               np.asarray(jax.nn.sigmoid(margin)),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(label),
                                  np.asarray(proba > 0.5, np.float32))
    assert set(np.unique(np.asarray(label))) <= {0.0, 1.0}
    with pytest.raises(ValueError, match="unknown output"):
        m.predict(x, output="logits")


def test_predict_outputs_mse():
    x, y = _toy(seed=5, objective="mse")
    cfg = repro.GBDTConfig(n_trees=3, max_depth=3, n_candidates=8,
                           objective="mse")
    m = repro.fit(x, y, cfg, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(m.predict(x)),
                                  np.asarray(m.predict(x, output="margin")))
    with pytest.raises(ValueError, match="proba"):
        m.predict(x, output="proba")
    assert repro.mape(m, x, y) >= 0.0


def test_predict_margin_alias_warns_and_matches():
    x, y = _toy(seed=6)
    cfg = repro.GBDTConfig(n_trees=2, max_depth=3, n_candidates=8)
    m = repro.fit(x, y, cfg, jax.random.PRNGKey(0))
    with pytest.warns(DeprecationWarning, match="predict_margin"):
        old = m.predict_margin(x)
    np.testing.assert_array_equal(np.asarray(old),
                                  np.asarray(m.predict(x, output="margin")))


def test_metrics_route_through_predict():
    x, y = _toy(seed=7)
    cfg = repro.GBDTConfig(n_trees=2, max_depth=3, n_candidates=8)
    m = repro.fit(x, y, cfg, jax.random.PRNGKey(0))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        acc = repro.accuracy(m, x, y)      # must not touch deprecated API
    assert 0.0 <= acc <= 1.0
    with pytest.raises(ValueError, match="classification"):
        cfg_mse = repro.GBDTConfig(n_trees=2, max_depth=3, n_candidates=8,
                                   objective="mse")
        repro.accuracy(repro.fit(x, y, cfg_mse, jax.random.PRNGKey(0)),
                       x, y)
