"""The repro.obs telemetry layer (TrainReport).

Pins: (1) telemetry is off by default and costs nothing (report is None,
forest identical), (2) with telemetry on the scanned trainer emits one
TrainReport row per round whose fields are internally consistent with
the fitted forest, (3) the JSON schema and host-side summary, (4) the
distributed collective-byte estimator.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import obs
from repro.core import boosting, tree as tree_lib


def _toy(n=2000, f=5, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, f))
    w = jax.random.normal(jax.random.fold_in(key, 1), (f,))
    y = (x @ w > 0).astype(jnp.float32)
    return x, y


def _cfg(**kw):
    base = dict(n_trees=5, max_depth=4, n_candidates=16)
    base.update(kw)
    return repro.GBDTConfig(**base)


def test_telemetry_off_by_default():
    x, y = _toy()
    m = repro.fit(x, y, _cfg(), jax.random.PRNGKey(0))
    assert m.config.telemetry is False
    assert m.report is None


def test_report_shapes_and_consistency():
    x, y = _toy(seed=1)
    cfg = _cfg(telemetry=True)
    m = repro.fit(x, y, cfg, jax.random.PRNGKey(0))
    rep = m.report
    assert isinstance(rep, repro.TrainReport)
    assert rep.n_rounds == cfg.n_trees
    for field in rep:
        assert field.shape == (cfg.n_trees,)

    n_splits = np.asarray(rep.n_splits)
    # n_splits is exactly the number of non-passthrough inner nodes of
    # each fitted tree — the report describes the forest it rode with
    realized = (np.asarray(m.forest.feature) >= 0).sum(axis=1)
    np.testing.assert_array_equal(n_splits, realized)
    assert (n_splits <= 2 ** cfg.max_depth - 1).all()

    gains_max = np.asarray(rep.best_gain_max)
    gains_mean = np.asarray(rep.best_gain_mean)
    assert (gains_max >= gains_mean).all() and (gains_mean >= 0).all()
    assert (np.asarray(rep.grad_norm) > 0).all()
    assert (np.asarray(rep.hess_norm) > 0).all()
    # single host: no collectives
    assert (np.asarray(rep.all_gather_bytes) == 0).all()
    assert (np.asarray(rep.psum_bytes) == 0).all()

    # direct growth scatters every row at every level: n * f * depth
    n, f = 2000, 5
    np.testing.assert_array_equal(np.asarray(rep.hist_updates),
                                  np.full(cfg.n_trees,
                                          n * f * cfg.max_depth, np.float32))


def test_subtract_hist_updates_below_direct():
    """The measured scatter-update counter audits the subtraction win:
    strictly fewer updates than direct growth, same forest."""
    x, y = _toy(seed=1)
    key = jax.random.PRNGKey(0)
    m_dir = repro.fit(x, y, _cfg(telemetry=True), key)
    m_sub = repro.fit(x, y, _cfg(telemetry=True, subtract=True), key)
    for a, b in zip(m_sub.forest, m_dir.forest):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    up_dir = np.asarray(m_dir.report.hist_updates)
    up_sub = np.asarray(m_sub.report.hist_updates)
    assert (up_sub > 0).all()
    assert (up_sub < up_dir).all(), (up_sub, up_dir)
    # level 0 is all-LEFT (full root scatter); levels > 0 scatter only
    # LEFT-routed rows, so the total sits between 1/depth and 1x
    assert (up_sub >= up_dir / m_dir.config.max_depth).all()


def test_loss_curve_decreases_on_learnable_data():
    x, y = _toy(seed=2)
    m = repro.fit(x, y, _cfg(n_trees=8, telemetry=True),
                  jax.random.PRNGKey(0))
    loss = np.asarray(m.report.train_loss)
    assert loss[-1] < loss[0]
    # post-update loss of round 0 equals an independent evaluation
    margin0 = float(np.asarray(obs.mean_train_loss(
        jnp.asarray(m.base_score
                    + m.config.learning_rate * np.asarray(
                        tree_lib.predict_raw(m.trees[0], x,
                                             max_depth=m.config.max_depth)),
                    jnp.float32),
        y, "logistic")))
    assert loss[0] == pytest.approx(margin0, abs=1e-5)


def test_telemetry_does_not_change_the_forest():
    x, y = _toy(seed=3)
    key = jax.random.PRNGKey(4)
    m_on = repro.fit(x, y, _cfg(telemetry=True), key)
    m_off = repro.fit(x, y, _cfg(), key)
    for a, b in zip(m_on.forest, m_off.forest):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mean_train_loss_matches_numpy():
    rng = np.random.default_rng(0)
    margin = rng.normal(size=64).astype(np.float32)
    y = (rng.random(64) > 0.5).astype(np.float32)
    got = float(obs.mean_train_loss(jnp.asarray(margin), jnp.asarray(y),
                                    "logistic"))
    p = 1 / (1 + np.exp(-margin.astype(np.float64)))
    want = -(y * np.log(p) + (1 - y) * np.log(1 - p)).mean()
    assert got == pytest.approx(want, rel=1e-5)
    got_mse = float(obs.mean_train_loss(jnp.asarray(margin),
                                        jnp.asarray(y), "mse"))
    assert got_mse == pytest.approx(0.5 * ((margin - y) ** 2).mean(),
                                    rel=1e-5)
    with pytest.raises(ValueError, match="unknown objective"):
        obs.mean_train_loss(jnp.asarray(margin), jnp.asarray(y), "huber")


def test_build_tree_return_stats_matches_tree():
    x, y = _toy(800, 4, seed=5)
    key = jax.random.PRNGKey(1)
    from repro.core import binning, proposal
    c = proposal.propose("random", x, 8, key=key)
    bins = binning.bin_features(x, c)
    g, h = boosting.grad_hess(jnp.zeros(x.shape[0]), y, "logistic")
    spec = repro.HistSpec(n_nodes=8, nbins=9, n_levels=4).resolved()
    t, stats = tree_lib.build_tree(bins, jnp.stack([g, h], 1), c,
                                   max_depth=4, spec=spec,
                                   return_stats=True)
    assert int(stats.n_splits) == int((np.asarray(t.feature) >= 0).sum())
    assert float(stats.gain_max) >= 0.0
    assert float(stats.gain_sum) >= float(stats.gain_max)
    assert float(stats.hist_updates) == 800 * 4 * 4   # n * f * depth


def test_summary_and_json_schema():
    x, y = _toy(seed=6)
    m = repro.fit(x, y, _cfg(telemetry=True), jax.random.PRNGKey(0))
    s = m.report.summarize()
    assert {"n_rounds", "train_loss", "grad_norm", "splits", "best_gain",
            "collective_bytes", "scatter_updates"} <= set(s)
    json.dumps(s)                              # everything serialisable

    rec = json.loads(m.report.to_json())
    assert rec["schema"] == "repro.obs.TrainReport/v2"
    assert rec["n_rounds"] == m.config.n_trees
    assert set(rec["rounds"]) == set(repro.TrainReport._fields)
    for vals in rec["rounds"].values():
        assert len(vals) == m.config.n_trees


def test_to_json_writes_file(tmp_path):
    x, y = _toy(seed=7)
    m = repro.fit(x, y, _cfg(n_trees=3, telemetry=True),
                  jax.random.PRNGKey(0))
    path = tmp_path / "report.json"
    m.report.to_json(str(path))
    assert json.loads(path.read_text())["n_rounds"] == 3


def test_collective_bytes_estimator():
    cfg = _cfg(n_trees=4, telemetry=True)     # random strategy
    ag, ps = obs.collective_bytes_per_round(cfg, n_features=16,
                                            n_workers=8)
    assert ag.shape == ps.shape == (4,)
    # all_gather: W * f * k floats, every round (repropose default)
    assert (ag == 8 * 16 * cfg.n_candidates * 4).all()
    frontier = 2 ** (cfg.max_depth - 1)
    hist = cfg.max_depth * frontier * 16 * cfg.nbins * 2 * 4
    leaf = 2 ** cfg.max_depth * 2 * 4
    assert (ps == hist + leaf + 4 * 4).all()

    # subtraction growth: only the half-width left panels are psum'd
    cfg_sub = _cfg(n_trees=4, telemetry=True, subtract=True)
    _, ps_sub = obs.collective_bytes_per_round(cfg_sub, n_features=16,
                                               n_workers=8)
    assert (ps_sub == hist // 2 + leaf + 4 * 4).all()

    # fixed grid: proposal collectives happen in round 0 only
    cfg_fix = _cfg(n_trees=4, repropose_each_round=False)
    ag_f, _ = obs.collective_bytes_per_round(cfg_fix, 16, 8)
    assert ag_f[0] > 0 and (ag_f[1:] == 0).all()

    # uniform_range proposes via pmin/pmax (psum column), not all_gather
    cfg_u = _cfg(strategy="uniform_range")
    ag_u, ps_u = obs.collective_bytes_per_round(cfg_u, 16, 8)
    assert (ag_u == 0).all() and (ps_u > hist + leaf - 1).all()
