"""Attention substrate invariants: chunked == full, decode == prefill."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention as attn


def _cfg(**kw):
    base = get_config("glm4-9b", smoke=True)
    return dataclasses.replace(base, **kw)


def _inputs(cfg, b=2, s=256, seed=0):
    key = jax.random.PRNGKey(seed)
    p = attn.init_attention(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1),
                          (b, s, cfg.d_model), jnp.float32) * 0.3
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    return p, x, pos


@pytest.mark.parametrize("window", [0, 64])
def test_chunked_equals_full(window):
    cfg_full = _cfg(attn_impl="xla_full")
    cfg_chunk = _cfg(attn_impl="xla_chunked", attn_chunk=64)
    p, x, pos = _inputs(cfg_full)
    y_full = attn.attention(p, cfg_full, x, pos, window=window)
    y_chunk = attn.attention(p, cfg_chunk, x, pos, window=window)
    np.testing.assert_allclose(np.asarray(y_full, np.float32),
                               np.asarray(y_chunk, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_causal_skip_equals_baseline():
    """The §Perf causal-skip optimization must be numerically identical."""
    cfg_base = _cfg(attn_impl="xla_chunked", attn_chunk=64)
    cfg_skip = dataclasses.replace(cfg_base, causal_skip=True)
    p, x, pos = _inputs(cfg_base)
    y0 = attn.attention(p, cfg_base, x, pos)
    y1 = attn.attention(p, cfg_skip, x, pos)
    np.testing.assert_allclose(np.asarray(y0, np.float32),
                               np.asarray(y1, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_unrolled_equals_scanned():
    cfg_base = _cfg(attn_impl="xla_chunked", attn_chunk=64)
    cfg_unroll = dataclasses.replace(cfg_base, scan_chunks=False)
    p, x, pos = _inputs(cfg_base)
    y0 = attn.attention(p, cfg_base, x, pos)
    y1 = attn.attention(p, cfg_unroll, x, pos)
    np.testing.assert_allclose(np.asarray(y0, np.float32),
                               np.asarray(y1, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_decode_matches_prefill():
    """Token-by-token decode with KV cache reproduces the causal prefill
    logits (the serving correctness invariant)."""
    cfg = _cfg(attn_impl="xla_full")
    b, s = 2, 16
    p, x, pos = _inputs(cfg, b=b, s=s)
    y_prefill = attn.attention(p, cfg, x, pos)
    cache = attn.init_kv_cache(cfg, b, s, dtype=jnp.float32)
    outs = []
    for t in range(s):
        y, cache = attn.attention_decode(
            p, cfg, x[:, t:t + 1], cache, jnp.full((b,), t, jnp.int32))
        outs.append(y)
    y_decode = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(y_prefill, np.float32),
                               np.asarray(y_decode, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_decode_sliding_window_ring_buffer():
    """With window W, decode attends to exactly the last W tokens."""
    cfg = _cfg(attn_impl="xla_full")
    W = 8
    b, s = 1, 24
    p, x, pos = _inputs(cfg, b=b, s=s)
    y_win = attn.attention(p, cfg, x, pos, window=W)       # oracle
    cache = attn.init_kv_cache(cfg, b, W, dtype=jnp.float32)
    outs = []
    for t in range(s):
        y, cache = attn.attention_decode(
            p, cfg, x[:, t:t + 1], cache, jnp.full((b,), t, jnp.int32),
            window=W)
        outs.append(y)
    y_decode = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(y_win, np.float32),
                               np.asarray(y_decode, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_gqa_heads_grouping():
    """GQA output must differ from MHA with same weights truncated — i.e.
    grouping actually shares K/V across query-head groups."""
    cfg = _cfg(attn_impl="xla_full")
    assert cfg.n_heads % cfg.n_kv_heads == 0 and \
        cfg.n_heads != cfg.n_kv_heads
    p, x, pos = _inputs(cfg)
    y = attn.attention(p, cfg, x, pos)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
