"""The assigned architecture table, asserted EXACTLY (one test per arch)."""

import pytest

from repro.configs import ARCH_NAMES, INPUT_SHAPES, get_config

EXPECTED = {
    # name: (family, L, d_model, H, kv, d_ff, vocab, extras)
    "deepseek-moe-16b": ("moe", 28, 2048, 16, 16, 1408, 102_400,
                         dict(n_experts=64, top_k=6, n_shared_experts=2)),
    "granite-34b": ("dense", 88, 6144, 48, 1, 24_576, 49_152, {}),
    "qwen3-moe-235b-a22b": ("moe", 94, 4096, 64, 4, 1536, 151_936,
                            dict(n_experts=128, top_k=8)),
    "internvl2-1b": ("vlm", 24, 896, 14, 2, 4864, 151_655, {}),
    "granite-20b": ("dense", 52, 6144, 48, 1, 24_576, 49_152, {}),
    "xlstm-125m": ("ssm", 12, 768, 4, 4, 0, 50_304, {}),
    "qwen2.5-14b": ("dense", 48, 5120, 40, 8, 13_824, 152_064,
                    dict(qkv_bias=True)),
    "whisper-tiny": ("audio", 4, 384, 6, 6, 1536, 51_865,
                     dict(is_encoder_decoder=True)),
    "glm4-9b": ("dense", 40, 4096, 32, 2, 13_696, 151_552, {}),
    "zamba2-2.7b": ("hybrid", 54, 2560, 32, 32, 10_240, 32_000,
                    dict(ssm_state=64)),
}


def test_all_ten_archs_present():
    assert set(ARCH_NAMES) == set(EXPECTED)


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_exact_assigned_numbers(arch):
    fam, L, d, h, kv, dff, v, extras = EXPECTED[arch]
    cfg = get_config(arch)
    assert cfg.family == fam
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == h
    assert cfg.n_kv_heads == kv
    assert cfg.d_ff == dff
    assert cfg.vocab_size == v
    for k, val in extras.items():
        assert getattr(cfg, k) == val, (k, getattr(cfg, k), val)


def test_input_shapes_table():
    t = INPUT_SHAPES
    assert (t["train_4k"].seq_len, t["train_4k"].global_batch) == (4096, 256)
    assert (t["prefill_32k"].seq_len, t["prefill_32k"].global_batch) == \
        (32_768, 32)
    assert (t["decode_32k"].seq_len, t["decode_32k"].global_batch) == \
        (32_768, 128)
    assert (t["long_500k"].seq_len, t["long_500k"].global_batch) == \
        (524_288, 1)
    assert t["train_4k"].kind == "train"
    assert t["decode_32k"].kind == "decode"


def test_long_500k_skips():
    """Sub-quadratic policy: enc-dec whisper skips; recurrent archs run
    natively; quadratic archs run via the sliding-window variant."""
    assert not get_config("whisper-tiny").supports_shape("long_500k")
    assert get_config("xlstm-125m").supports_shape("long_500k")
    assert get_config("zamba2-2.7b").supports_shape("long_500k")
    cfg = get_config("glm4-9b")
    assert cfg.supports_shape("long_500k")
    assert cfg.long_context_window > 0   # window variant, per DESIGN.md
