"""Optimizer / checkpoint / data-pipeline substrate tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev dependency; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro import checkpoint as ckpt
from repro.data import TokenPipeline, make_dataset, tabular
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_lr


# --- optimizer -------------------------------------------------------------

def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=100)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_grad_clip_caps_update():
    cfg = AdamWConfig(lr=1.0, clip_norm=1e-3, weight_decay=0.0,
                      warmup_steps=0)
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params)
    _, _, gnorm = adamw_update(params, {"w": jnp.full(3, 1e6)}, state, cfg)
    assert float(gnorm) > 1e5          # reported norm is pre-clip


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110)
    assert float(cosine_lr(cfg, 0)) == pytest.approx(0.0)
    assert float(cosine_lr(cfg, 10)) == pytest.approx(1.0, abs=1e-2)
    assert float(cosine_lr(cfg, 110)) == pytest.approx(0.0, abs=1e-6)
    assert float(cosine_lr(cfg, 60)) == pytest.approx(0.5, abs=0.05)


# --- checkpoint ------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16),
                  "d": jnp.asarray(3, jnp.int32)}}
    path = ckpt.save_checkpoint(str(tmp_path), 7, tree)
    assert ckpt.latest_step(str(tmp_path)) == 7
    target = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
    out = ckpt.restore_checkpoint(path, target)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    tree = {"a": jnp.ones((4,))}
    path = ckpt.save_checkpoint(str(tmp_path), 0, tree)
    bad = {"a": jax.ShapeDtypeStruct((5,), jnp.float32)}
    with pytest.raises(ValueError):
        ckpt.restore_checkpoint(path, bad)


def test_latest_step_empty(tmp_path):
    assert ckpt.latest_step(str(tmp_path / "nope")) is None


# --- data ------------------------------------------------------------------

def test_token_pipeline_deterministic_and_sharded():
    pipe = TokenPipeline(vocab_size=1000, seq_len=32, global_batch=8)
    b1 = pipe.batch_at(3)
    b2 = pipe.batch_at(3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert b1["tokens"].shape == (8, 32)
    assert int(b1["tokens"].max()) < 1000
    # shards tile the global batch exactly
    shards = [pipe.shard_at(3, w, 4)["tokens"] for w in range(4)]
    np.testing.assert_array_equal(np.asarray(jnp.concatenate(shards)),
                                  np.asarray(b1["tokens"]))


@given(seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_gaussian_classification_learnable(seed):
    x, y = tabular.gaussian_classification(500, 10, seed)
    assert x.shape == (500, 10) and set(np.unique(y)) <= {0.0, 1.0}
    assert np.isfinite(x).all()


def test_make_dataset_splits():
    xtr, ytr, xte, yte, task = make_dataset("susy-like", 1000, 200)
    assert xtr.shape == (1000, 18) and xte.shape == (200, 18)
    assert task == "class"
    xtr, ytr, xte, yte, task = make_dataset("pjm-like", 500, 100)
    assert task == "reg"


def test_ar1_series_is_noniid():
    """Paper: random sampling handles non-iid data; the series generator
    must actually BE autocorrelated."""
    x, y = tabular.ar1_series(2000, 10, seed=0)
    r = np.corrcoef(y[:-1], y[1:])[0, 1]
    assert r > 0.9
