"""Distributed GBDT (Algorithm 1) — runs in a subprocess with 8 forced
host devices so the main test process keeps its single-device view."""

import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow        # subprocess retrain, >60s

_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import Mesh
from repro.core import boosting, distributed

key = jax.random.PRNGKey(7)
n, f = 8192, 6
X = jax.random.normal(key, (n, f))
w = jax.random.normal(jax.random.fold_in(key, 1), (f,))
y = (X @ w > 0).astype(jnp.float32)
mesh = Mesh(np.array(jax.devices()).reshape(8,), ("data",))

out = {"n_devices": len(jax.devices())}
for strat in ("random", "weighted_quantile"):
    cfg = boosting.GBDTConfig(n_trees=4, max_depth=4, n_candidates=16,
                              strategy=strat)
    m = distributed.fit_distributed(X, y, cfg, mesh, key)
    out[strat] = boosting.accuracy(m, X, y)

# single-host reference with identical config
cfg = boosting.GBDTConfig(n_trees=4, max_depth=4, n_candidates=16)
m1 = boosting.fit(X, y, cfg, key)
out["single"] = boosting.accuracy(m1, X, y)
print("RESULT" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def dist_result():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def test_runs_on_8_workers(dist_result):
    assert dist_result["n_devices"] == 8


def test_distributed_random_learns(dist_result):
    assert dist_result["random"] > 0.85


def test_distributed_random_matches_quantile(dist_result):
    """Paper claim, distributed: S ~= Q accuracy."""
    assert abs(dist_result["random"] - dist_result["weighted_quantile"]) \
        < 0.03, dist_result


def test_distributed_matches_single_host(dist_result):
    """Algorithm 1 with psum'd histograms ~= single-host training."""
    assert abs(dist_result["random"] - dist_result["single"]) < 0.03, \
        dist_result


# ---------------------------------------------------------------------------
# Padding correctness: n % n_workers != 0.
#
# The driver pads shards with repeats of the leading rows; those rows
# must carry zero weight so they never bias the base score, the psum'd
# histograms, or the leaf values.  With 'uniform_range' the distributed
# candidate grid is IDENTICAL to the single-host one (pmin/pmax of
# duplicated rows == global min/max), so the padded distributed fit must
# agree with the single-host fit oracle tree-for-tree — the strongest
# possible regression check for the padding bias.
# ---------------------------------------------------------------------------

_PAD_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import Mesh
from repro.core import boosting, distributed

key = jax.random.PRNGKey(7)
n, f = 1003, 4                       # 1003 % 8 = 3 -> 5 pad rows
X = jax.random.normal(key, (n, f))
w = jax.random.normal(jax.random.fold_in(key, 1), (f,))
y = (X @ w > 0).astype(jnp.float32)
mesh = Mesh(np.array(jax.devices()).reshape(8,), ("data",))

cfg = boosting.GBDTConfig(n_trees=4, max_depth=4, n_candidates=8,
                          strategy="uniform_range")
md = distributed.fit_distributed(X, y, cfg, mesh, key)
mr = distributed.fit_distributed(X, y, cfg, mesh, key, reference=True)
ms = boosting.fit(X, y, cfg, key)

def forest_cmp(a, b):
    return {
        "feature_equal": bool(np.array_equal(np.asarray(a.feature),
                                             np.asarray(b.feature))),
        "split_bin_equal": bool(np.array_equal(np.asarray(a.split_bin),
                                               np.asarray(b.split_bin))),
        "threshold_close": bool(np.allclose(np.asarray(a.threshold),
                                            np.asarray(b.threshold),
                                            atol=1e-6)),
        "leaf_close": bool(np.allclose(np.asarray(a.leaf_value),
                                       np.asarray(b.leaf_value),
                                       atol=1e-4)),
    }

# weighted_quantile on padded data must also train fine (no crash, sane
# accuracy) even though its merged candidate grid is not the single-host one
cfg_wq = boosting.GBDTConfig(n_trees=4, max_depth=4, n_candidates=8,
                             strategy="weighted_quantile")
m_wq = distributed.fit_distributed(X, y, cfg_wq, mesh, key)

out = {
    "n_devices": len(jax.devices()),
    "vs_single": forest_cmp(md.forest, ms.forest),
    "scan_vs_ref": forest_cmp(md.forest, mr.forest),
    "base_gap": abs(md.base_score - ms.base_score),
    "acc_dist": boosting.accuracy(md, X, y),
    "acc_single": boosting.accuracy(ms, X, y),
    "acc_wq": boosting.accuracy(m_wq, X, y),
}
print("RESULT" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def pad_result():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    proc = subprocess.run([sys.executable, "-c", _PAD_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def test_padded_fit_matches_single_host_oracle(pad_result):
    """n % nw != 0: pad rows carry zero weight, so the distributed fit
    reproduces the single-host trees exactly (uniform_range grid)."""
    assert pad_result["n_devices"] == 8
    assert all(pad_result["vs_single"].values()), pad_result
    assert pad_result["base_gap"] < 1e-5, pad_result
    assert pad_result["acc_dist"] == pytest.approx(
        pad_result["acc_single"], abs=1e-6)


def test_padded_scan_matches_reference_worker(pad_result):
    """The scanned worker and the unrolled oracle agree under padding."""
    assert all(pad_result["scan_vs_ref"].values()), pad_result


def test_padded_weighted_quantile_trains(pad_result):
    assert pad_result["acc_wq"] > 0.85, pad_result
