"""Distributed GBDT (Algorithm 1) — runs in a subprocess with 8 forced
host devices so the main test process keeps its single-device view."""

import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow        # subprocess retrain, >60s

_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import Mesh
from repro.core import boosting, distributed

key = jax.random.PRNGKey(7)
n, f = 8192, 6
X = jax.random.normal(key, (n, f))
w = jax.random.normal(jax.random.fold_in(key, 1), (f,))
y = (X @ w > 0).astype(jnp.float32)
mesh = Mesh(np.array(jax.devices()).reshape(8,), ("data",))

out = {"n_devices": len(jax.devices())}
for strat in ("random", "weighted_quantile"):
    cfg = boosting.GBDTConfig(n_trees=4, max_depth=4, n_candidates=16,
                              strategy=strat)
    m = distributed.fit_distributed(X, y, cfg, mesh, key)
    out[strat] = boosting.accuracy(m, X, y)

# single-host reference with identical config
cfg = boosting.GBDTConfig(n_trees=4, max_depth=4, n_candidates=16)
m1 = boosting.fit(X, y, cfg, key)
out["single"] = boosting.accuracy(m1, X, y)
print("RESULT" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def dist_result():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def test_runs_on_8_workers(dist_result):
    assert dist_result["n_devices"] == 8


def test_distributed_random_learns(dist_result):
    assert dist_result["random"] > 0.85


def test_distributed_random_matches_quantile(dist_result):
    """Paper claim, distributed: S ~= Q accuracy."""
    assert abs(dist_result["random"] - dist_result["weighted_quantile"]) \
        < 0.03, dist_result


def test_distributed_matches_single_host(dist_result):
    """Algorithm 1 with psum'd histograms ~= single-host training."""
    assert abs(dist_result["random"] - dist_result["single"]) < 0.03, \
        dist_result
