"""Per-architecture smoke tests: REDUCED same-family variants (<=2 layers,
d_model<=512, <=4 experts) run one forward/train step on CPU, asserting
output shapes and no NaNs, plus a serve-step decode — as required by the
assignment brief."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.launch import steps
from repro.models import model
from repro.optim import AdamWConfig, adamw_init


def _batch(cfg, b=2, s=64, seed=0):
    key = jax.random.PRNGKey(seed)
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (b, cfg.n_frontend_tokens, cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (b, cfg.n_frontend_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_reduced_config_limits(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 64
    batch = _batch(cfg, b, s)
    logits, aux = model.forward(params, cfg, batch)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_one_train_step(arch):
    cfg = get_config(arch, smoke=True)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = steps.make_train_step(cfg, opt_cfg)
    batch = _batch(cfg)
    p2, o2, metrics = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["gnorm"]) > 0.0
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         params, p2)
    assert max(jax.tree.leaves(moved)) > 0.0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_serve_step_decode(arch):
    cfg = get_config(arch, smoke=True)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    b, cache = 2, 32
    state = model.init_decode_state(cfg, b, cache)
    serve = steps.make_serve_step(cfg)
    tok = jnp.zeros((b, 1), jnp.int32)
    pos = jnp.full((b,), 3, jnp.int32)
    logits, state2 = jax.jit(serve)(params, state, tok, pos)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # state must change somewhere
    diff = jax.tree.map(lambda a, b: float(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)).max()), state, state2)
    assert max(jax.tree.leaves(diff)) > 0.0


def test_loss_decreases_tiny_lm():
    """A few steps on repetitive data must reduce the loss (dense family
    as the representative; the full sweep would be slow on 1 CPU)."""
    cfg = get_config("glm4-9b", smoke=True)
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=0, total_steps=50)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(steps.make_train_step(cfg, opt_cfg))
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (2, 64), 0, 32)   # tiny vocab slice
    batch = {"tokens": toks}
    losses = []
    for _ in range(8):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_microbatched_step_matches_plain():
    cfg = get_config("glm4-9b", smoke=True)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                          clip_norm=1e9)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = _batch(cfg, b=4)
    s1 = jax.jit(steps.make_train_step(cfg, opt_cfg, microbatches=1))
    s2 = jax.jit(steps.make_train_step(cfg, opt_cfg, microbatches=2))
    p1, o1, m1 = s1(params, opt, batch)
    p2, o2, m2 = s2(params, opt, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-3)
    # compare the accumulated grads (first moments): Adam's step-1 update
    # normalises g/|g|, so tiny fp noise flips signs on ~zero grads —
    # the gradients themselves must agree
    g1 = jnp.concatenate([a.ravel() for a in jax.tree.leaves(o1["m"])])
    g2 = jnp.concatenate([a.ravel() for a in jax.tree.leaves(o2["m"])])
    scale = float(jnp.abs(g1).max())
    assert float(jnp.abs(g1 - g2).max()) < 5e-3 * scale + 1e-7
