"""Batched forest inference engine (repro.core.predict) contracts.

The engine's one promise is *bit-identity* with the per-tree descent it
replaces, across every backend and chunk size — a fast predictor that
drifts by a ulp is a different model in production.  These tests pin:

  * batched raw traversal == per-tree ``_descend_raw`` oracle sum for
    backends ref / packed / interpret (Pallas kernel, interpret mode)
    and chunk sizes 1 / 7 / n_trees, including NaN rows and chunk
    padding (chunk sizes that do not divide n_trees);
  * binned traversal == raw traversal on finite rows binned against the
    training grid (thresholds ARE grid boundaries, so routing agrees);
  * the NaN contract: raw NaN compares False and routes RIGHT at every
    node; binned NaN lands in the LAST bin and follows bin routing;
  * the jitted+donated margin path is bit-identical to the historical
    eager ``base + lr * sum`` (the FMA-contraction pitfall);
  * empty (0, f) batches return (0,) without tracing;
  * ``tree.forest_predict_raw`` still works but warns DeprecationWarning;
  * checkpoint save/load round-trips to bit-identical predictions.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import load_gbdt, save_gbdt
from repro.core import boosting, predict as predict_lib, tree as tree_lib
from repro.kernels.ops import TraverseSpec
from repro.launch.serve_gbdt import synthetic_gbdt


N_TREES, DEPTH, F, K = 13, 4, 6, 8


@pytest.fixture(scope="module")
def model():
    return synthetic_gbdt(n_trees=N_TREES, max_depth=DEPTH, n_features=F,
                          n_candidates=K, seed=7, passthrough_frac=0.25)


@pytest.fixture(scope="module")
def x_nan():
    """Raw rows, a few of them containing NaNs."""
    rng = np.random.default_rng(42)
    x = rng.normal(size=(97, F)).astype(np.float32)
    x[::11, 0] = np.nan
    x[5, :] = np.nan
    return jnp.asarray(x)


def _oracle_sum(forest, x, max_depth):
    """Ensemble sum via the unbatched per-tree descent."""
    acc = jnp.zeros((x.shape[0],), jnp.float32)
    for t in tree_lib.forest_trees(forest):
        acc = acc + tree_lib._descend_raw(t, x, max_depth)
    return np.asarray(acc)


@pytest.mark.parametrize("backend", ["ref", "packed", "interpret"])
@pytest.mark.parametrize("chunk", [1, 7, N_TREES])
def test_batched_matches_per_tree_oracle(model, x_nan, backend, chunk):
    # chunk=7 does not divide 13 trees: exercises passthrough padding
    base = _oracle_sum(model.forest, x_nan, DEPTH)
    out = predict_lib.forest_predict(model.forest, x_nan, max_depth=DEPTH,
                                     tree_chunk=chunk, backend=backend)
    assert np.array_equal(np.asarray(out), base), (backend, chunk)


@pytest.mark.parametrize("backend", ["ref", "packed", "interpret"])
def test_binned_matches_raw_on_finite_rows(model, backend):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(64, F)).astype(np.float32))
    bins = model.bin_features(x)
    raw = predict_lib.forest_predict(model.forest, x, max_depth=DEPTH,
                                     tree_chunk=5, backend=backend)
    binned = predict_lib.forest_predict(model.forest, bins, max_depth=DEPTH,
                                        binned=True, tree_chunk=5,
                                        backend=backend)
    assert np.array_equal(np.asarray(raw), np.asarray(binned)), backend


def test_nan_contract_raw_routes_right(model):
    """A NaN feature value fails every ``x <= thr`` comparison, so an
    all-NaN row must land in the rightmost reachable leaf of each tree
    — identically in the engine and the per-tree oracle."""
    x = jnp.full((3, F), np.nan, jnp.float32)
    base = _oracle_sum(model.forest, x, DEPTH)
    out = predict_lib.forest_predict(model.forest, x, max_depth=DEPTH,
                                     tree_chunk=4)
    assert np.array_equal(np.asarray(out), base)
    # and the oracle itself is the all-right spine: descend by hand
    for t in tree_lib.forest_trees(model.forest):
        node = 0
        for _ in range(DEPTH):
            node = node * 2 + 1                 # NaN -> go_left False
        expect = float(t.leaf_value[node])
        got = float(tree_lib._descend_raw(t, x, DEPTH)[0])
        assert got == expect


def test_nan_contract_binned_is_last_bin(model):
    """bin_features sends NaN to the last bin (#{c_i < NaN} semantics),
    so a binned NaN row follows the last bin's routing — in particular
    it goes LEFT at passthrough nodes (split_bin = nbins-1), unlike the
    raw path.  Pin the bin id and that the engine follows it."""
    x = jnp.full((2, F), np.nan, jnp.float32)
    bins = model.bin_features(x)
    assert int(jnp.max(bins)) == int(jnp.min(bins)) == K  # last bin id
    out = predict_lib.forest_predict(model.forest, bins, max_depth=DEPTH,
                                     binned=True, tree_chunk=4)
    acc = jnp.zeros((2,), jnp.float32)
    for t in tree_lib.forest_trees(model.forest):
        acc = acc + tree_lib._descend_binned(t, bins, DEPTH)
    assert np.array_equal(np.asarray(out), np.asarray(acc))


def test_margin_path_bit_identical_to_eager(model):
    """GBDTModel.predict routes every output mode through ONE jitted
    traversal; the closing affine transform must reproduce the eager
    ``base + lr * sum`` bit-for-bit (no FMA contraction drift)."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(200, F)).astype(np.float32))
    total = predict_lib.forest_predict(model.forest, x, max_depth=DEPTH)
    eager = model.base_score + model.config.learning_rate * total
    spec = TraverseSpec(binned=False).resolved()
    m = predict_lib.margin(model.forest, x, model.base_score,
                           model.config.learning_rate,
                           max_depth=DEPTH, spec=spec)
    assert np.array_equal(np.asarray(m), np.asarray(eager))
    assert np.array_equal(np.asarray(model.predict(x, output="margin")),
                          np.asarray(eager))


def test_empty_batch_returns_empty(model):
    x0 = jnp.zeros((0, F), jnp.float32)
    out = predict_lib.forest_predict(model.forest, x0, max_depth=DEPTH)
    assert out.shape == (0,)
    m = model.predict(x0, output="margin")
    assert m.shape == (0,)


def test_forest_predict_raw_shim_warns(model):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(16, F)).astype(np.float32))
    with pytest.warns(DeprecationWarning, match="forest_predict_raw"):
        old = tree_lib.forest_predict_raw(model.forest, x, max_depth=DEPTH)
    new = predict_lib.forest_predict(model.forest, x, max_depth=DEPTH)
    assert np.array_equal(np.asarray(old), np.asarray(new))


def test_checkpoint_roundtrip_bit_identical(model, tmp_path):
    path = tmp_path / "model.npz"
    save_gbdt(path, model)
    loaded = load_gbdt(path)
    assert loaded.config == model.config
    assert loaded.base_score == model.base_score
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(50, F)).astype(np.float32))
    for output in ("margin",):
        assert np.array_equal(np.asarray(model.predict(x, output=output)),
                              np.asarray(loaded.predict(x, output=output)))
    # binned serving path survives the round trip too (grid persisted)
    bins = loaded.bin_features(x)
    assert np.array_equal(
        np.asarray(model.predict(x, output="margin")),
        np.asarray(loaded.predict(bins, output="margin", binned=True)))


def test_model_predict_binned_accepts_raw_and_prebinned(model):
    """predict(..., binned=True) bins float input itself; pre-binned
    integer input is used as-is — both match the raw path exactly."""
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(40, F)).astype(np.float32))
    raw = np.asarray(model.predict(x, output="margin"))
    auto = np.asarray(model.predict(x, output="margin", binned=True))
    pre = np.asarray(model.predict(model.bin_features(x), output="margin",
                                   binned=True))
    assert np.array_equal(raw, auto)
    assert np.array_equal(raw, pre)
