"""Mini dry-run: the full lower+compile+roofline pipeline on a 2x2 debug
mesh with reduced configs (subprocess: needs 4 forced host devices).

The production 512-chip dry-run is exercised by
``python -m repro.launch.dryrun --all`` (see EXPERIMENTS.md); this test
guards the machinery itself so regressions surface in CI time.
"""

import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow        # subprocess lower+compile sweep, >60s

_SCRIPT = r"""
import json
import repro.configs.base as base
from repro.configs.base import InputShape
base.INPUT_SHAPES["train_4k"] = InputShape("train_4k", 256, 8, "train")
base.INPUT_SHAPES["prefill_32k"] = InputShape("prefill_32k", 512, 4, "prefill")
base.INPUT_SHAPES["decode_32k"] = InputShape("decode_32k", 512, 8, "decode")
base.INPUT_SHAPES["long_500k"] = InputShape("long_500k", 2048, 1, "decode")
from repro.configs import get_config
from repro.launch.dryrun import run_one
from repro.launch.mesh import make_debug_mesh

mesh = make_debug_mesh(2, 2)
out = {}
for arch in ["glm4-9b", "deepseek-moe-16b", "xlstm-125m", "zamba2-2.7b",
             "whisper-tiny"]:
    cfg = get_config(arch, smoke=True)
    for sname in ["train_4k", "decode_32k"]:
        rec = run_one(arch, sname, multi_pod=False, cfg=cfg, mesh=mesh,
                      verbose=False)
        out[f"{arch}/{sname}"] = {
            "status": rec["status"],
            "dominant": rec.get("roofline", {}).get("dominant"),
            "flops": rec.get("flops", 0),
            "error": rec.get("error", "")[:200],
        }
print("RESULT" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def mini_dryrun():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=2400)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def test_all_combos_compile(mini_dryrun):
    bad = {k: v for k, v in mini_dryrun.items() if v["status"] != "ok"}
    assert not bad, bad


def test_roofline_terms_present(mini_dryrun):
    for k, v in mini_dryrun.items():
        assert v["dominant"] in ("compute", "memory", "collective"), (k, v)
        assert v["flops"] > 0, (k, v)
