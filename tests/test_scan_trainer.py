"""Seed-equivalence of the single-compile lax.scan trainers.

The scanned trainers (boosting.fit, distributed._worker_fit) must
reproduce the kept-as-reference unrolled loops tree-for-tree on a fixed
PRNG seed: identical feature / split_bin / threshold / leaf_value
arrays and identical accuracy, for both the paper's 'random' strategy
and the weighted-quantile baseline.  The distributed check runs in a
subprocess with 8 forced host devices (same harness as
test_distributed.py).
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import boosting, tree as tree_lib


def _toy(n=4000, f=6, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, f))
    w = jax.random.normal(jax.random.fold_in(key, 1), (f,))
    y = (x @ w > 0).astype(jnp.float32)
    return x, y


def _assert_forests_match(fa: tree_lib.Forest, fb: tree_lib.Forest):
    np.testing.assert_array_equal(np.asarray(fa.feature),
                                  np.asarray(fb.feature))
    np.testing.assert_array_equal(np.asarray(fa.split_bin),
                                  np.asarray(fb.split_bin))
    np.testing.assert_allclose(np.asarray(fa.threshold),
                               np.asarray(fb.threshold), atol=1e-6)
    np.testing.assert_allclose(np.asarray(fa.leaf_value),
                               np.asarray(fb.leaf_value), atol=1e-5)


@pytest.mark.parametrize("strategy", ["random", "weighted_quantile"])
def test_scanned_fit_matches_reference(strategy):
    x, y = _toy()
    cfg = boosting.GBDTConfig(n_trees=6, max_depth=4, n_candidates=16,
                              strategy=strategy)
    key = jax.random.PRNGKey(3)
    m_scan = boosting.fit(x, y, cfg, key)
    m_ref = boosting.fit_reference(x, y, cfg, key)
    _assert_forests_match(m_scan.forest, m_ref.forest)
    np.testing.assert_allclose(np.asarray(m_scan.candidates),
                               np.asarray(m_ref.candidates), atol=1e-6)
    assert boosting.accuracy(m_scan, x, y) == \
        pytest.approx(boosting.accuracy(m_ref, x, y), abs=1e-6)


@pytest.mark.parametrize("strategy", ["random", "weighted_quantile"])
def test_scanned_fit_with_telemetry_matches_reference(strategy):
    """Telemetry rows ride the scan as extra outputs — turning them on
    must not change a single split or leaf vs the unrolled oracle."""
    x, y = _toy(seed=8)
    cfg = boosting.GBDTConfig(n_trees=6, max_depth=4, n_candidates=16,
                              strategy=strategy, telemetry=True)
    key = jax.random.PRNGKey(3)
    m_scan = boosting.fit(x, y, cfg, key)
    m_ref = boosting.fit_reference(
        x, y, boosting.GBDTConfig(n_trees=6, max_depth=4, n_candidates=16,
                                  strategy=strategy), key)
    _assert_forests_match(m_scan.forest, m_ref.forest)
    assert m_scan.report is not None
    assert m_scan.report.n_rounds == cfg.n_trees


@pytest.mark.parametrize("strategy", ["random", "weighted_quantile"])
def test_subtract_fit_matches_reference(strategy):
    """Histogram-subtraction growth (GBDTConfig.subtract) is a pure perf
    policy: tree-for-tree identical forests vs the direct-growth scanned
    trainer AND the unrolled fit_reference oracle on a pinned seed."""
    x, y = _toy()
    key = jax.random.PRNGKey(3)
    cfg_sub = boosting.GBDTConfig(n_trees=6, max_depth=4, n_candidates=16,
                                  strategy=strategy, subtract=True)
    cfg_dir = boosting.GBDTConfig(n_trees=6, max_depth=4, n_candidates=16,
                                  strategy=strategy)
    m_sub = boosting.fit(x, y, cfg_sub, key)
    m_dir = boosting.fit(x, y, cfg_dir, key)
    m_ref = boosting.fit_reference(x, y, cfg_dir, key)
    _assert_forests_match(m_sub.forest, m_dir.forest)
    _assert_forests_match(m_sub.forest, m_ref.forest)
    assert boosting.accuracy(m_sub, x, y) == \
        pytest.approx(boosting.accuracy(m_ref, x, y), abs=1e-6)


def test_subtract_depth_one_matches_reference():
    """frontier == 1 edge: level 0 is all-LEFT by construction, the
    subtraction panel IS the root histogram."""
    x, y = _toy(1000, 4, seed=9)
    key = jax.random.PRNGKey(2)
    cfg_sub = boosting.GBDTConfig(n_trees=3, max_depth=1, n_candidates=8,
                                  subtract=True)
    cfg_dir = boosting.GBDTConfig(n_trees=3, max_depth=1, n_candidates=8)
    _assert_forests_match(boosting.fit(x, y, cfg_sub, key).forest,
                          boosting.fit_reference(x, y, cfg_dir, key).forest)


def test_scanned_fit_matches_reference_no_repropose():
    x, y = _toy(seed=2)
    cfg = boosting.GBDTConfig(n_trees=5, max_depth=4, n_candidates=16,
                              repropose_each_round=False)
    key = jax.random.PRNGKey(1)
    m_scan = boosting.fit(x, y, cfg, key)
    m_ref = boosting.fit_reference(x, y, cfg, key)
    _assert_forests_match(m_scan.forest, m_ref.forest)
    assert m_scan.candidates.shape[0] == 1     # proposed once
    assert m_ref.candidates.shape[0] == 1


def test_forest_predict_matches_per_tree_loop():
    """Vectorized stacked-tree predictor == per-tree Python loop."""
    x, y = _toy(seed=4)
    cfg = boosting.GBDTConfig(n_trees=5, max_depth=4, n_candidates=16)
    m = boosting.fit(x, y, cfg, jax.random.PRNGKey(0))
    looped = np.full((x.shape[0],), m.base_score, np.float32)
    for t in m.trees:
        looped = looped + cfg.learning_rate * np.asarray(
            tree_lib.predict_raw(t, x, max_depth=cfg.max_depth))
    np.testing.assert_allclose(np.asarray(m.predict(x, output="margin")),
                               looped, atol=1e-4)


def test_host_strategy_stays_outside_scan():
    """gk_quantile proposes on the host once; the scanned trainer still
    matches the reference loop (candidates are x-only, so re-proposing
    each round is the identity)."""
    x, y = _toy(1000, 4, seed=6)
    cfg = boosting.GBDTConfig(n_trees=3, max_depth=3, n_candidates=8,
                              strategy="gk_quantile")
    key = jax.random.PRNGKey(5)
    m_scan = boosting.fit(x, y, cfg, key)
    m_ref = boosting.fit_reference(x, y, cfg, key)
    _assert_forests_match(m_scan.forest, m_ref.forest)
    assert m_scan.proposal_seconds > 0.0       # timed host proposal
    # host-side strategies are x-only: BOTH trainers report the single
    # proposed grid as (1, f, k) — the unified leading-axis convention
    assert m_scan.candidates.shape == (1, 4, 8)
    assert m_ref.candidates.shape == (1, 4, 8)
    np.testing.assert_allclose(np.asarray(m_scan.candidates),
                               np.asarray(m_ref.candidates), atol=1e-6)


# ---------------------------------------------------------------------------
# Distributed: scanned worker vs unrolled oracle on 8 forced host devices.
# ---------------------------------------------------------------------------

_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import Mesh
from repro.core import boosting, distributed

key = jax.random.PRNGKey(7)
n, f = 8192, 6
X = jax.random.normal(key, (n, f))
w = jax.random.normal(jax.random.fold_in(key, 1), (f,))
y = (X @ w > 0).astype(jnp.float32)
mesh = Mesh(np.array(jax.devices()).reshape(8,), ("data",))

out = {"n_devices": len(jax.devices())}
for strat in ("random", "weighted_quantile"):
    cfg = boosting.GBDTConfig(n_trees=4, max_depth=4, n_candidates=16,
                              strategy=strat)
    ms = distributed.fit_distributed(X, y, cfg, mesh, key)
    mr = distributed.fit_distributed(X, y, cfg, mesh, key, reference=True)
    # subtraction growth: half-width psum panels, same trees
    cfg_sub = boosting.GBDTConfig(n_trees=4, max_depth=4, n_candidates=16,
                                  strategy=strat, subtract=True,
                                  telemetry=True)
    msub = distributed.fit_distributed(X, y, cfg_sub, mesh, key)
    out[strat] = {
        "feature_equal": bool(np.array_equal(np.asarray(ms.forest.feature),
                                             np.asarray(mr.forest.feature))),
        "split_bin_equal": bool(np.array_equal(
            np.asarray(ms.forest.split_bin),
            np.asarray(mr.forest.split_bin))),
        "threshold_close": bool(np.allclose(
            np.asarray(ms.forest.threshold),
            np.asarray(mr.forest.threshold), atol=1e-6)),
        "leaf_close": bool(np.allclose(
            np.asarray(ms.forest.leaf_value),
            np.asarray(mr.forest.leaf_value), atol=1e-5)),
        "sub_feature_equal": bool(np.array_equal(
            np.asarray(msub.forest.feature),
            np.asarray(mr.forest.feature))),
        "sub_split_bin_equal": bool(np.array_equal(
            np.asarray(msub.forest.split_bin),
            np.asarray(mr.forest.split_bin))),
        "sub_threshold_close": bool(np.allclose(
            np.asarray(msub.forest.threshold),
            np.asarray(mr.forest.threshold), atol=1e-6)),
        "sub_leaf_close": bool(np.allclose(
            np.asarray(msub.forest.leaf_value),
            np.asarray(mr.forest.leaf_value), atol=1e-5)),
        "sub_psum_bytes": float(np.asarray(
            msub.report.psum_bytes).sum()),
        "sub_hist_updates": float(np.asarray(
            msub.report.hist_updates).sum()),
        "acc_scan": boosting.accuracy(ms, X, y),
        "acc_ref": boosting.accuracy(mr, X, y),
    }

# telemetry'd direct fit for the psum / scatter-update comparison
cfg_dtel = boosting.GBDTConfig(n_trees=4, max_depth=4, n_candidates=16,
                               telemetry=True)
mdir = distributed.fit_distributed(X, y, cfg_dtel, mesh, key)
out["direct_psum_bytes"] = float(np.asarray(mdir.report.psum_bytes).sum())
out["direct_hist_updates"] = float(
    np.asarray(mdir.report.hist_updates).sum())
print("RESULT" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def dist_equiv():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


@pytest.mark.slow
@pytest.mark.parametrize("strategy", ["random", "weighted_quantile"])
def test_distributed_scan_matches_reference(dist_equiv, strategy):
    assert dist_equiv["n_devices"] == 8
    r = dist_equiv[strategy]
    assert r["feature_equal"] and r["split_bin_equal"], r
    assert r["threshold_close"] and r["leaf_close"], r
    assert r["acc_scan"] == pytest.approx(r["acc_ref"], abs=1e-6), r


@pytest.mark.slow
@pytest.mark.parametrize("strategy", ["random", "weighted_quantile"])
def test_distributed_subtract_matches_reference(dist_equiv, strategy):
    """subtract=True on the mesh: only half-width left panels cross the
    psum, yet the forest is tree-for-tree the unrolled oracle's."""
    r = dist_equiv[strategy]
    assert r["sub_feature_equal"] and r["sub_split_bin_equal"], r
    assert r["sub_threshold_close"] and r["sub_leaf_close"], r


@pytest.mark.slow
def test_distributed_subtract_halves_collectives(dist_equiv):
    """The point of the policy: psum bytes and measured scatter updates
    drop vs direct growth (hist term exactly halved; leaf/telemetry
    terms unchanged, so the total is strictly between 0.5x and 1x)."""
    sub_ps = dist_equiv["random"]["sub_psum_bytes"]
    dir_ps = dist_equiv["direct_psum_bytes"]
    assert 0.5 * dir_ps < sub_ps < dir_ps, (sub_ps, dir_ps)
    sub_up = dist_equiv["random"]["sub_hist_updates"]
    dir_up = dist_equiv["direct_hist_updates"]
    assert sub_up < 0.75 * dir_up, (sub_up, dir_up)
    assert sub_up > 0
