"""Theorem 1: expected rank error of random candidate subsets.

Property tests (hypothesis) of the closed form against Monte-Carlo, plus
the paper's Fig.2 claim: deterministic quantile binning is statistically
indistinguishable from random selection.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev dependency; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core import rank_error as re_mod


def test_closed_form_values():
    # E[R] = (n-k)/(k+1)
    assert re_mod.expected_rank_error(100, 100) == 0.0
    assert re_mod.expected_rank_error(100, 1) == pytest.approx(99 / 2)
    assert re_mod.normalized_rank_error(1000, 9) == pytest.approx(0.1)


@given(n=st.integers(10, 400), k=st.integers(1, 9))
@settings(max_examples=20, deadline=None)
def test_normalized_error_is_1_over_k_plus_1(n, k):
    k = min(k, n - 1)
    assert re_mod.normalized_rank_error(n, k) == pytest.approx(1 / (k + 1))


@pytest.mark.parametrize("n,k", [(200, 4), (200, 16), (500, 9)])
def test_monte_carlo_matches_theorem(n, k):
    """E[R] over random subsets ~= (n-k)/(k+1); rank error is independent
    of the objective, so any fixed f works."""
    key = jax.random.PRNGKey(0)
    f = re_mod.smooth_random_objective(key, n)
    est = float(re_mod.mc_rank_error_random(key, f, k, trials=4000))
    expect = re_mod.expected_rank_error(n, k)
    assert est == pytest.approx(expect, rel=0.15), (est, expect)


def test_rank_error_of_subset_basics():
    f = jnp.asarray([0.1, 5.0, 2.0, 0.3])
    # subset containing the argmax -> 0
    assert int(re_mod.rank_error_of_subset(f, jnp.asarray([0, 1]))) == 0
    # subset with only the 3rd best -> rank 2
    assert int(re_mod.rank_error_of_subset(f, jnp.asarray([3]))) == 2


def test_fig2_quantile_equivalent_to_random():
    """The paper's Fig.2: quantile bins show the same mean normalised rank
    error as random selection (both ~1/(k+1)); neither can exploit f."""
    out = re_mod.fig2_experiment(seed=0, n=512, ks=[4, 8, 16], trials=24)
    for r, q, t in zip(out["random"], out["quantile"], out["theory"]):
        assert r == pytest.approx(t, rel=0.5)
        assert q == pytest.approx(t, rel=0.6)
        # and the two strategies are close to EACH OTHER (the claim)
        assert abs(r - q) < 0.6 * t + 0.02
