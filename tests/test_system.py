"""End-to-end behaviour tests for the paper's system.

The paper's three claims, verified at test scale:
  1. random split sampling reaches quantile-sketch accuracy (DT + GBDT);
  2. random proposal is cheaper than sketch building;
  3. the distributed trainer (Algorithm 1) preserves both.
(3) lives in test_distributed.py; (1)-(2) here, on the synthetic
analogues of the paper's dataset families.
"""

import time

import jax
import numpy as np
import pytest

from repro.core import boosting
from repro.data import make_dataset


@pytest.mark.parametrize("ds", ["susy-like", "higgs-like"])
def test_gbdt_random_vs_quantile_classification(ds):
    xtr, ytr, xte, yte, task = make_dataset(ds, 8000, 2000)
    accs = {}
    for strat in ("random", "weighted_quantile", "uniform_range"):
        cfg = boosting.GBDTConfig(n_trees=10, max_depth=5, n_candidates=16,
                                  strategy=strat)
        m = boosting.fit(xtr, ytr, cfg, jax.random.PRNGKey(0))
        accs[strat] = boosting.accuracy(m, xte, yte)
    # all strategies within noise of each other (Table 2)
    vals = list(accs.values())
    assert max(vals) - min(vals) < 0.04, accs
    assert accs["random"] > 0.6


def test_gbdt_regression_mape_parity():
    xtr, ytr, xte, yte, task = make_dataset("pjm-like", 6000, 1500)
    mapes = {}
    for strat in ("random", "weighted_quantile"):
        cfg = boosting.GBDTConfig(n_trees=20, max_depth=5, n_candidates=16,
                                  strategy=strat, objective="mse")
        m = boosting.fit(xtr, ytr, cfg, jax.random.PRNGKey(1))
        pred = np.asarray(m.predict(xte))
        mapes[strat] = float(np.mean(np.abs(
            (pred - yte) / np.where(np.abs(yte) < 0.1, 1.0, yte))))
    assert abs(mapes["random"] - mapes["weighted_quantile"]) < \
        0.3 * max(mapes.values()) + 0.05, mapes


def test_random_proposal_cheaper_than_gk():
    """T(S) < T(Q) — the paper's timing claim.  GK summary is the honest
    streaming baseline; random sampling must beat it comfortably."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(60_000, 8)).astype(np.float32)
    from repro.core import proposal
    key = jax.random.PRNGKey(0)
    # warm up jit
    jax.block_until_ready(proposal.random_candidates(key, x, 16))
    t0 = time.perf_counter()
    for i in range(3):
        jax.block_until_ready(proposal.random_candidates(
            jax.random.fold_in(key, i), x, 16))
    t_random = (time.perf_counter() - t0) / 3
    t0 = time.perf_counter()
    proposal.gk_quantile_candidates(x[:20_000], 16)   # 1/3 of the rows!
    t_gk = time.perf_counter() - t0
    assert t_random < t_gk, (t_random, t_gk)


def test_variance_across_seeds_is_small():
    """Paper: 'variance of accuracies across runs < 0.001'."""
    xtr, ytr, xte, yte, _ = make_dataset("susy-like", 6000, 1500)
    accs = []
    for seed in range(3):
        cfg = boosting.GBDTConfig(n_trees=8, max_depth=4, n_candidates=16)
        m = boosting.fit(xtr, ytr, cfg, jax.random.PRNGKey(seed))
        accs.append(boosting.accuracy(m, xte, yte))
    assert float(np.var(accs)) < 0.001, accs
