"""GK quantile summary: the epsilon rank guarantee (property test)."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev dependency; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core import sketch


@given(n=st.integers(50, 2000), seed=st.integers(0, 100),
       eps=st.sampled_from([0.05, 0.1, 0.2]))
@settings(max_examples=15, deadline=None)
def test_gk_rank_guarantee(n, seed, eps):
    rng = np.random.default_rng(seed)
    data = rng.normal(size=n).astype(np.float32)
    sk = sketch.GKSummary(eps)
    sk.extend(data)
    s = np.sort(data)
    for phi in (0.1, 0.25, 0.5, 0.75, 0.9):
        v = sk.query(phi)
        # actual rank of the answer
        r = np.searchsorted(s, v, side="right")
        target = int(np.ceil(phi * n))
        assert abs(r - target) <= 2 * eps * n + 1, (phi, r, target)


def test_gk_summary_is_compact():
    rng = np.random.default_rng(0)
    sk = sketch.GKSummary(0.05)
    sk.extend(rng.normal(size=5000))
    sk.compress()
    # GK guarantees O((1/eps) log(eps n)) tuples; generous bound
    assert len(sk) < 1500


def test_gk_candidates_sorted_unique():
    rng = np.random.default_rng(1)
    c = sketch.gk_candidates(rng.normal(size=3000), 16)
    assert np.all(np.diff(c) >= 0)
    assert len(c) <= 16


def test_weighted_quantiles_skew():
    """Candidates concentrate where the hessian mass is."""
    import jax.numpy as jnp
    v = jnp.linspace(0.0, 1.0, 1000)
    w = jnp.where(v < 0.2, 10.0, 0.1)    # mass at the left
    c = sketch.weighted_quantiles(v, w, 9)
    assert float(jnp.median(c)) < 0.3
    # uniform weights -> evenly spread
    cu = sketch.weighted_quantiles(v, jnp.ones_like(v), 9)
    assert float(jnp.median(cu)) == pytest.approx(0.5, abs=0.05)
