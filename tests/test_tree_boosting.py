"""Tree builder + GBDT trainer behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import binning, boosting, proposal, tree as tree_lib


def _toy(n=4000, f=6, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, f))
    w = jax.random.normal(jax.random.fold_in(key, 1), (f,))
    y = (x @ w > 0).astype(jnp.float32)
    return x, y


def test_single_tree_separates_axis_aligned():
    """A depth-1 tree must find an axis-aligned split exactly."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2000, 3))
    y = (x[:, 1] > 0.37).astype(jnp.float32)
    g = (jax.nn.sigmoid(jnp.zeros(2000)) - y).astype(jnp.float32)
    h = jnp.full((2000,), 0.25, jnp.float32)
    c = proposal.propose("exact", x, 64)
    bins = binning.bin_features(x, c)
    t = tree_lib.build_tree(bins, jnp.stack([g, h], 1), c,
                            max_depth=1, nbins=65)
    assert int(t.feature[0]) == 1
    assert abs(float(t.threshold[0]) - 0.37) < 0.1
    # left leaf negative class -> negative... leaf values have opposite
    # signs for the two classes
    assert float(t.leaf_value[0]) * float(t.leaf_value[1]) < 0


def test_predict_binned_equals_raw():
    x, y = _toy()
    cfg = boosting.GBDTConfig(n_trees=3, max_depth=4, n_candidates=16)
    m = boosting.fit(x, y, cfg)
    c = m.candidates[-1]
    bins = binning.bin_features(x, c)
    for t in m.trees[-1:]:
        pb = tree_lib.predict_binned(t, bins, max_depth=4)
        pr = tree_lib.predict_raw(t, x, max_depth=4)
        np.testing.assert_allclose(np.asarray(pb), np.asarray(pr))


def test_boosting_loss_decreases():
    x, y = _toy()
    cfg = boosting.GBDTConfig(n_trees=8, max_depth=4, n_candidates=16)
    m = boosting.fit(x, y, cfg)
    # train logloss after each prefix of trees must be non-increasing
    margins = jnp.full((x.shape[0],), m.base_score)
    losses = []
    for t in m.trees:
        margins = margins + cfg.learning_rate * tree_lib.predict_raw(
            t, x, max_depth=cfg.max_depth)
        p = jax.nn.sigmoid(margins)
        losses.append(float(-jnp.mean(y * jnp.log(p + 1e-9)
                                      + (1 - y) * jnp.log(1 - p + 1e-9))))
    assert losses[-1] < losses[0]
    assert losses == sorted(losses, reverse=True) or \
        losses[-1] < losses[0] * 0.9


def test_regression_mse_decreases():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (3000, 5))
    y = x[:, 0] * 2 + jnp.sin(3 * x[:, 1])
    cfg = boosting.GBDTConfig(n_trees=10, max_depth=4, n_candidates=16,
                              objective="mse")
    m = boosting.fit(x, y, cfg)
    pred = m.predict(x)
    mse = float(jnp.mean((pred - y) ** 2))
    base = float(jnp.mean((y - y.mean()) ** 2))
    assert mse < 0.5 * base


def test_random_matches_quantile_accuracy():
    """The paper's Table 2 claim at unit-test scale."""
    x, y = _toy(6000, 8, seed=5)
    xtr, ytr, xte, yte = x[:5000], y[:5000], x[5000:], y[5000:]
    accs = {}
    for s in ("random", "weighted_quantile"):
        cfg = boosting.GBDTConfig(n_trees=8, max_depth=4, n_candidates=16,
                                  strategy=s)
        m = boosting.fit(xtr, ytr, cfg, jax.random.PRNGKey(0))
        accs[s] = boosting.accuracy(m, xte, yte)
    assert abs(accs["random"] - accs["weighted_quantile"]) < 0.03, accs


def test_min_child_weight_blocks_splits():
    x, y = _toy(500, 3)
    cfg = boosting.GBDTConfig(n_trees=1, max_depth=3, n_candidates=8,
                              min_child_weight=1e9)
    m = boosting.fit(x, y, cfg)
    t = m.trees[0]
    assert bool(jnp.all(t.feature == -1))          # all passthrough
    # passthrough tree predicts a constant
    pr = tree_lib.predict_raw(t, x, max_depth=3)
    assert float(jnp.std(pr)) == pytest.approx(0.0, abs=1e-6)
